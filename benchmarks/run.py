"""Benchmark driver: one function per paper table/figure plus perf micros.

Prints ``name,us_per_call,derived`` CSV rows. Figure benchmarks are cached in
experiments/results/*.json (delete to re-run). ``--figs`` selects a subset.
"""
from __future__ import annotations

import argparse
import sys
import time


def _perf_micros():
    """Microbenchmarks of the core engine + kernels (CPU wall time)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.simulate import SimConfig, run_sim
    from repro.core.workloads import get_workload

    rows = []
    prog = get_workload("comd")
    sim = SimConfig(n_epochs=200)
    run_sim(prog, sim, "pcstall")  # warm compile
    t0 = time.perf_counter()
    run_sim(prog, sim, "pcstall")
    dt = (time.perf_counter() - t0) / 200 * 1e6
    rows.append(("sim_epoch_pcstall_64cu", dt, "us/epoch"))

    from repro.kernels import ops
    q = jnp.asarray(np.random.randn(2, 256, 4, 64), jnp.float32)
    k = jnp.asarray(np.random.randn(2, 256, 2, 64), jnp.float32)
    v = jnp.asarray(np.random.randn(2, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)  # warm
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        ops.flash_attention(q, k, v, causal=True).block_until_ready()
    rows.append(("pallas_flash_attn_interp_256", (time.perf_counter() - t0) / 3 * 1e6,
                 "us/call (interpret mode)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--figs", default="all",
                    help="comma list of figure names, 'all', or 'none'")
    ap.add_argument("--skip-micros", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if not args.skip_micros:
        for name, us, derived in _perf_micros():
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    from benchmarks.paper_figs import ALL_FIGS
    names = (list(ALL_FIGS) if args.figs == "all"
             else [] if args.figs == "none" else args.figs.split(","))
    for name in names:
        t0 = time.perf_counter()
        res = ALL_FIGS[name]()
        dt = (time.perf_counter() - t0) * 1e6
        # one-line derived summary per figure
        if name == "fig14_accuracy":
            d = res["MEAN"]
            summary = " ".join(f"{m}={d[m]:.2f}" for m in
                               ("crisp", "accreac", "pcstall", "accpc", "oracle"))
        elif name == "fig15_ed2p":
            d = res["GEOMEAN"]
            summary = " ".join(f"{m}={d[m]:.2f}" for m in
                               ("static22", "crisp", "pcstall", "oracle"))
        elif name == "fig01_epoch_sweep":
            summary = " ".join(f"{T}us:pc={v['ed2p']['pcstall']:.2f}/or={v['ed2p']['oracle']:.2f}"
                               for T, v in res.items())
        elif name == "fig07_variation":
            summary = " ".join(f"{T}us={v:.2f}" for T, v in res["epoch_sweep"].items())
        elif name == "fig10_pc_stability":
            summary = f"mean_samePC_var={res['MEAN']:.3f}"
        elif name == "fig11b_offset_sweep":
            summary = " ".join(f"{k}={v:.2f}" for k, v in res.items())
        elif name == "fig18a_energy_caps":
            summary = " ".join(f"{o}:pc={v['pcstall']:.3f}" for o, v in res.items())
        elif name == "fig18b_granularity":
            summary = " ".join(f"{g}:pc={v['pcstall']:.2f}" for g, v in res.items())
        else:
            summary = "ok"
        print(f"{name},{dt:.0f},{summary}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
