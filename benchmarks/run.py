"""Benchmark driver: one function per paper table/figure plus perf micros.

Prints ``name,us_per_call,derived`` CSV rows. Figure benchmarks are cached in
experiments/results/*.json (delete to re-run). ``--figs`` selects a subset.

Perf micros report first-call compile time *separately* from steady-state
us/epoch (the jit-cached engine pays tracing once per (SimStatic, mechanism);
the seed engine paid it on every call), the sweep benchmark times the
batched ``run_suite`` fig15 path (a 1-point ``run_grid`` — the single
dispatch family every sweep uses) against the seed-style serial path
(re-traced per call), the grid benchmark times a whole
(epoch_us x objective) figure grid through the device-sharded ``run_grid``
against a per-point ``run_suite`` loop (interleaved timings; the grid side
additionally dedupes mechanisms to one scan per exec-axes equivalence
class), the grid_ema benchmark isolates the spec-driven reactive
dedup on a table_ema-only axis (``dedup=True`` vs ``dedup=False``), and
the grid_ivr benchmark sweeps whole IVR/hardware regimes (the traced
``power`` axis) through one grid dispatch against a per-point loop, and
the serve_stream benchmark drives a trace-driven request stream through
the live ``DVFSService`` (sustained jobs/sec + p99 dispatch latency,
<= 2 fork-family compiles asserted, streamed rows bitwise vs the one-shot
``run_grid`` loop, plus forced 1-/2-device subprocess arms in full mode),
and the learn benchmark times the learned-predictor pipeline end to end
(run_grid labeled-data factory, jit AdamW step, frozen-spec deployment on
held-out workloads with interleaved learned-vs-pcstall dispatch timings).
Results are also written to ``BENCH_sweep.json`` at the repo root so the
speedups are recorded in the repo's perf trajectory.

``--quick`` is the CI smoke mode: tiny sweep, no figure cache, <=30 s —
pair it with ``pytest -m "not slow"`` for a single fast CI job.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_sweep.json"


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _perf_micros(quick: bool = False):
    """Microbenchmarks of the core engine + kernels (CPU wall time).

    Returns (rows, record) — rows for CSV printing, record for
    BENCH_sweep.json."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import simulate as SIM
    from repro.core.simulate import SimConfig, run_sim
    from repro.core.workloads import get_workload

    rows = []
    n_ep = 100 if quick else 200
    prog = get_workload("comd")
    sim = SimConfig(n_epochs=n_ep)

    # seed-style dispatch: the un-jitted scan re-traces on every call (what
    # the seed engine did for each of its ~100 sweep calls)
    def seed_style():
        jax.block_until_ready(SIM._scan_sim(
            prog, jnp.int32(prog.n_blocks), jnp.int32(0),
            sim.static_part(), sim.axes(), "pcstall"))
    seed_us = _time_once(seed_style) / n_ep * 1e6

    compile_s = _time_once(lambda: run_sim(prog, sim, "pcstall"))
    reps = 2 if quick else 4
    steady_us = min(_time_once(lambda: run_sim(prog, sim, "pcstall"))
                    for _ in range(reps)) / n_ep * 1e6
    rows.append(("sim_epoch_pcstall_64cu_compile", compile_s * 1e6,
                 "us first call (trace+compile; paid once)"))
    rows.append(("sim_epoch_pcstall_64cu", steady_us,
                 f"us/epoch steady-state ({seed_us / steady_us:.1f}x vs "
                 "seed-style re-trace)"))
    rows.append(("sim_epoch_pcstall_64cu_seed_style", seed_us,
                 "us/epoch with per-call re-trace (seed behavior)"))
    record = {"compile_ms": compile_s * 1e3,
              "steady_us_per_epoch": steady_us,
              "seed_style_us_per_epoch": seed_us,
              "speedup_steady_vs_seed_style": seed_us / steady_us,
              "n_epochs": n_ep}

    from repro.kernels import ops
    q = jnp.asarray(np.random.randn(2, 256, 4, 64), jnp.float32)
    k = jnp.asarray(np.random.randn(2, 256, 2, 64), jnp.float32)
    v = jnp.asarray(np.random.randn(2, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)  # warm
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        ops.flash_attention(q, k, v, causal=True).block_until_ready()
    rows.append(("pallas_flash_attn_interp_256", (time.perf_counter() - t0) / 3 * 1e6,
                 "us/call (interpret mode)"))
    return rows, record


def _bench_sweep(quick: bool = False):
    """fig15-style sweep: batched run_suite vs seed-style serial traces.

    Measured at two epoch scales: the seed path's cost is trace-dominated at
    short scans (where the suite's compile-once structure wins big) and
    execution-bound at long ones (where the win is the batched execute);
    both land in BENCH_sweep.json. Also checks batched-vs-serial numerics.

    Returns (rows, record)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import simulate as SIM
    from repro.core.simulate import SimConfig, run_sim
    from repro.core.sweep import run_suite
    from repro.core.workloads import get_workload
    from benchmarks.paper_figs import FAST_MECHS, WORKLOADS_FAST

    if quick:
        wls, mechs, scales = WORKLOADS_FAST[:2], ("static17", "pcstall"), \
            (("tiny", 80),)
    else:
        wls, mechs, scales = list(WORKLOADS_FAST), FAST_MECHS, \
            (("trace_bound_150ep", 150), ("exec_bound_400ep", 400))
    progs = {w: get_workload(w) for w in wls}

    rows, record = [], {"workloads": wls, "mechanisms": list(mechs)}
    for label, n_ep in scales:
        sim = SimConfig(n_epochs=n_ep)

        def serial_seed_style():
            return {w: {m: {k: np.asarray(v) for k, v in SIM._scan_sim(
                progs[w], jnp.int32(progs[w].n_blocks), jnp.int32(0),
                sim.static_part(), sim.axes(), m).items()}
                for m in mechs} for w in wls}
        serial_s = _time_once(serial_seed_style)

        t0 = time.perf_counter()
        suite = run_suite(progs, sim, mechs)
        suite_cold_s = time.perf_counter() - t0
        suite_warm_s = min(_time_once(lambda: run_suite(progs, sim, mechs))
                           for _ in range(2))

        # numerics: batched output vs the (jit-cached) serial engine.
        # max|dev| alone is hard to read: when the chaotic run_sim boundary
        # flips one frequency decision (see sweep.py docstring) a single
        # epoch's work diverges by O(work/epoch) and the per-epoch metric
        # saturates. The relative counterpart is the run-aggregate
        # work/energy deviation (worst workload x mechanism), which stays
        # tiny even across decision flips — both ride in the record.
        dev, rel_dev = 0.0, 0.0
        for w in wls:
            for m in mechs:
                ser = run_sim(progs[w], sim, m)
                for k in ser:
                    a = np.asarray(ser[k], np.float64)
                    b = np.asarray(suite[w][m][k], np.float64)
                    dev = max(dev, float(np.max(np.abs(a - b))))
                    if k in ("work", "energy"):
                        sa = float(np.sum(a))
                        if sa != 0.0:
                            rel_dev = max(rel_dev,
                                          abs(sa - float(np.sum(b)))
                                          / abs(sa))

        rows += [
            (f"sweep_fig15_serial_seed_style_{label}", serial_s * 1e6,
             f"{len(wls)}wl x {len(mechs)}mech x {n_ep}ep; re-trace/call"),
            (f"sweep_fig15_total_{label}", suite_cold_s * 1e6,
             f"run_suite cold incl compile ({serial_s / suite_cold_s:.1f}x)"),
            (f"sweep_fig15_warm_{label}", suite_warm_s * 1e6,
             f"run_suite jit-cache hit ({serial_s / suite_warm_s:.1f}x); "
             f"max|dev| vs serial {dev:.2g} (agg rel {rel_dev:.2g})"),
        ]
        record[label] = {
            "n_epochs": n_ep,
            "serial_seed_style_s": serial_s,
            "suite_cold_s": suite_cold_s,
            "suite_warm_s": suite_warm_s,
            "speedup_cold": serial_s / suite_cold_s,
            "speedup_warm": serial_s / suite_warm_s,
            "max_abs_dev_vs_serial": dev,
            "agg_rel_dev_vs_serial": rel_dev,
        }
    return rows, record


def _bench_kernel_epoch(quick: bool = False):
    """v2 fused epoch kernel vs the unfused jnp scan body, on the paper's
    64-CU pcstall hot loop (the same workload _perf_micros tracks).

    Timings are interleaved A/B/A/B per the bench-box protocol (2-core box
    — never benchmark concurrently; alternation cancels slow drift); min of
    each side is reported. The fused path runs the lean math (see
    kernels.epoch_fused), so the record also reports its numerics vs the
    jnp path: per-epoch max|dev| is O(work/epoch) — the argmin select flips
    on near-ties and the closed loop is chaotic — while the aggregate
    work/energy deviations stay O(1e-4) relative; both ride in the record.

    Returns (rows, record)."""
    import dataclasses

    import numpy as np
    from repro.core.simulate import SimConfig, run_sim
    from repro.core.workloads import get_workload

    n_ep = 100 if quick else 200
    prog = get_workload("comd")
    sim = SimConfig(n_epochs=n_ep)          # paper scale: 64 CU x 40 WF
    sim_v2 = dataclasses.replace(sim, use_pallas="v2")

    a = run_sim(prog, sim, "pcstall")       # warm both sides + numerics
    b = run_sim(prog, sim_v2, "pcstall")
    agg = {k: abs(float(np.sum(a[k])) - float(np.sum(b[k])))
           / abs(float(np.sum(a[k]))) for k in ("work", "energy")}
    dev = float(np.max(np.abs(np.asarray(a["work"], np.float64)
                              - np.asarray(b["work"], np.float64))))

    reps = 2 if quick else 4
    jnp_t, fused_t = [], []
    for _ in range(reps):
        jnp_t.append(_time_once(lambda: run_sim(prog, sim, "pcstall")))
        fused_t.append(_time_once(lambda: run_sim(prog, sim_v2, "pcstall")))
    jnp_us = min(jnp_t) / n_ep * 1e6
    fused_us = min(fused_t) / n_ep * 1e6

    rows = [
        ("kernel_epoch_jnp", jnp_us,
         f"us/epoch unfused jnp scan body (comd 64cu pcstall x {n_ep}ep)"),
        ("kernel_epoch_fused", fused_us,
         f"us/epoch v2 fused epoch kernel ({jnp_us / fused_us:.2f}x); "
         f"per-epoch max|dev| work {dev:.3g}; aggregate rel dev "
         f"work {agg['work']:.1e} / energy {agg['energy']:.1e}"),
    ]
    record = {"workload": "comd", "mechanism": "pcstall", "n_epochs": n_ep,
              "us_per_epoch_jnp": jnp_us,
              "us_per_epoch_fused": fused_us,
              "speedup": jnp_us / fused_us,
              "max_abs_dev_work_per_epoch": dev,
              "agg_rel_dev_work": agg["work"],
              "agg_rel_dev_energy": agg["energy"]}
    return rows, record


def _bench_grid_kernel(quick: bool = False):
    """Tentpole record: the fused epoch kernel as the GRID engine.

    One 64-CU multi-point ``run_grid`` over EVERY served mechanism family
    — the five traced fork mechanisms ride the v2 scan body inside the
    shared traced-id executable; static17/oracle (v2-incapable specs)
    fall back to the unfused body inside the SAME grid call — A/B against
    the identical grid on the jnp engine. Timings interleaved A/B/A/B per
    the bench-box protocol; min of each side reported.

    The v2 side's engine-mode contracts are asserted, not just recorded:
    <= 2 fork-family compiles for the whole grid, the exact deduped
    DISPATCH_ROWS accounting of the jnp engine, and run-aggregate
    work/energy within 1.1e-4 relative of the jnp engine for every
    (point, workload, mechanism) cell — the lean fork-row math never
    touches the selected row (see kernels.epoch_fused), so in practice
    the deviation is 0.0. The >= 1.3x warm acceptance target is asserted
    in full mode (quick is a smoke: contracts only).

    Returns (rows, record)."""
    import dataclasses

    import numpy as np
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import run_grid
    from repro.core.workloads import get_workload

    n_ep = 60 if quick else 200
    wls = ("comd", "hpgmg")
    fork_mechs = ("stall", "crisp", "accreac", "pcstall", "accpc")
    mechs = fork_mechs + ("static17", "oracle")
    progs = {w: get_workload(w) for w in wls}
    sim = SimConfig(n_epochs=n_ep)          # paper scale: 64 CU x 40 WF
    sim_v2 = dataclasses.replace(sim, use_pallas="v2")
    grid = {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]}
    n_pts = 4

    res_a = run_grid(progs, sim, grid, mechs)       # warm jnp side
    SW.reset_counters()
    res_b = run_grid(progs, sim_v2, grid, mechs)    # warm v2 + contracts
    fork_compiles = sum(v for k, v in SW.TRACE_COUNTS.items()
                        if k in ("grid_forks", "grid_oracle"))
    assert fork_compiles <= 2, \
        f"v2 grid compiled {fork_compiles} fork-family executables"
    # exact dedup-row accounting, identical to the jnp engine: one row
    # per (workload x point) for each traced fork mech, per point for
    # oracle, per (epoch_us) execution CLASS for static17 (objective is
    # dead for it — 2 classes on this 2x2 grid)
    assert SW.DISPATCH_ROWS["grid_forks"] == \
        len(wls) * n_pts * len(fork_mechs), SW.DISPATCH_ROWS
    assert SW.DISPATCH_ROWS["grid_oracle"] == len(wls) * n_pts, \
        SW.DISPATCH_ROWS
    assert SW.DISPATCH_ROWS["grid_static17"] == len(wls) * 2, \
        SW.DISPATCH_ROWS

    # numerics: run-aggregate work/energy per grid cell, worst case
    rel_dev = 0.0
    for key in res_a:
        for w in wls:
            for m in mechs:
                for ch in ("work", "energy"):
                    sa = float(np.sum(np.asarray(res_a[key][w][m][ch],
                                                 np.float64)))
                    sb = float(np.sum(np.asarray(res_b[key][w][m][ch],
                                                 np.float64)))
                    if sa != 0.0:
                        rel_dev = max(rel_dev, abs(sa - sb) / abs(sa))
    assert rel_dev <= 1.1e-4, \
        f"v2 grid aggregate deviation {rel_dev:.3g} exceeds 1.1e-4"

    reps = 2 if quick else 4
    jnp_t, v2_t = [], []
    for _ in range(reps):
        jnp_t.append(_time_once(lambda: run_grid(progs, sim, grid, mechs)))
        v2_t.append(_time_once(lambda: run_grid(progs, sim_v2, grid,
                                                mechs)))
    jnp_s, v2_s = min(jnp_t), min(v2_t)
    speedup = jnp_s / v2_s
    if not quick:
        assert speedup >= 1.3, \
            f"v2 grid warm speedup {speedup:.2f}x below the 1.3x target"

    rows = [
        ("grid_kernel_jnp", jnp_s * 1e6,
         f"warm run_grid, jnp engine ({n_pts}pt x {len(wls)}wl x "
         f"{len(mechs)}mech x {n_ep}ep, 64cu)"),
        ("grid_kernel_v2", v2_s * 1e6,
         f"warm run_grid, fused-kernel engine ({speedup:.2f}x); "
         f"{fork_compiles} fork-family compiles; worst agg rel dev "
         f"{rel_dev:.2g}; static/oracle fall back in-grid"),
    ]
    record = {"workloads": list(wls), "mechanisms": list(mechs),
              "n_epochs": n_ep, "grid_points": n_pts,
              "grid_warm_jnp_s": jnp_s, "grid_warm_v2_s": v2_s,
              "speedup_warm": speedup,
              "fork_family_compiles_v2": fork_compiles,
              "fork_mech_rows": SW.DISPATCH_ROWS["grid_forks"],
              "static_mech_rows_deduped": SW.DISPATCH_ROWS["grid_static17"],
              "agg_rel_dev_vs_jnp": rel_dev}
    return rows, record


def _bench_grid(quick: bool = False):
    """(epoch_us x objective) figure grid: one sharded ``run_grid``
    dispatch vs a per-point ``run_suite`` loop.

    Both paths benefit from the SimConfig split (the loop re-dispatches but
    does not re-trace across grid points) and both dispatch through the
    same grid executable family (run_suite IS a 1-point run_grid), so this
    isolates the win of batching the grid axes into one executable + fewer
    dispatches + the static-mechanism dedup (the 2x2 grid has 2 static
    execution classes, so static17 scans half its points). Timings are
    interleaved A/B/A/B (2-core box — never benchmark concurrently, and
    alternation cancels slow drift); min of each is reported.

    Returns (rows, record)."""
    import dataclasses

    import numpy as np
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import run_grid, run_suite
    from repro.core.workloads import get_workload
    from benchmarks.paper_figs import WORKLOADS_FAST

    # n_ep deliberately differs from _bench_sweep's scales (80/150/400) so
    # the loop path cannot reuse executables that benchmark already
    # compiled — "cold" must really pay the compile on both sides.
    if quick:
        wls, mechs, n_ep = WORKLOADS_FAST[:2], ("static17", "pcstall"), 60
    else:
        wls, mechs, n_ep = WORKLOADS_FAST[:6], \
            ("static17", "crisp", "pcstall", "oracle"), 200
    progs = {w: get_workload(w) for w in wls}
    cfg = SimConfig(n_epochs=n_ep)
    grid = {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]}
    # expand through the same helper run_grid uses, so the loop's keys
    # stay in lockstep with run_grid's result keys
    axis_names, points = SW._grid_points(grid)

    def loop_points():
        return {tuple(p[n] for n in axis_names):
                run_suite(progs, dataclasses.replace(cfg, **p), mechs)
                for p in points}

    def grid_call():
        return run_grid(progs, cfg, grid, mechs)

    SW.reset_counters()
    t0 = time.perf_counter()
    res_grid = grid_call()
    grid_cold_s = time.perf_counter() - t0
    fork_compiles = sum(v for k, v in SW.TRACE_COUNTS.items()
                        if k in ("grid_forks", "grid_oracle"))
    static_rows = sum(v for k, v in SW.DISPATCH_ROWS.items()
                      if k.startswith("grid_static"))
    fork_rows = SW.DISPATCH_ROWS["grid_forks"]
    t0 = time.perf_counter()
    res_loop = loop_points()
    loop_cold_s = time.perf_counter() - t0

    # warm path: interleave the two measurements
    reps = 2 if quick else 3
    loop_t, grid_t = [], []
    for _ in range(reps):
        loop_t.append(_time_once(loop_points))
        grid_t.append(_time_once(grid_call))
    loop_s, grid_s = min(loop_t), min(grid_t)

    # numerics: grid output vs the per-point suite loop
    dev = 0.0
    for key, suite in res_loop.items():
        for w in wls:
            for m in mechs:
                for k in suite[w][m]:
                    dev = max(dev, float(np.max(np.abs(
                        np.asarray(suite[w][m][k], np.float64)
                        - np.asarray(res_grid[key][w][m][k], np.float64)))))

    g = len(points)
    rows = [
        (f"grid_2x2_loop_cold", loop_cold_s * 1e6,
         f"{g}pt x {len(wls)}wl x {len(mechs)}mech x {n_ep}ep per-point "
         "run_suite loop"),
        (f"grid_2x2_total", grid_cold_s * 1e6,
         f"run_grid cold incl compile ({loop_cold_s / grid_cold_s:.1f}x); "
         f"{fork_compiles} fork-family compiles for the whole grid; "
         f"static dedup {static_rows} scan rows vs {fork_rows} fork "
         "mech-rows"),
        (f"grid_2x2_warm", grid_s * 1e6,
         f"run_grid jit-cache hit ({loop_s / grid_s:.1f}x vs warm loop); "
         f"max|dev| vs loop {dev:.2g}"),
        (f"grid_2x2_loop_warm", loop_s * 1e6, "per-point loop, jit-cached"),
    ]
    record = {"workloads": wls, "mechanisms": list(mechs), "n_epochs": n_ep,
              "grid_points": g,
              "loop_cold_s": loop_cold_s, "grid_cold_s": grid_cold_s,
              "loop_warm_s": loop_s, "grid_warm_s": grid_s,
              "speedup_cold": loop_cold_s / grid_cold_s,
              "speedup_warm": loop_s / grid_s,
              "fork_family_compiles": fork_compiles,
              "static_mech_rows_deduped": static_rows,
              "fork_mech_rows": fork_rows,
              "max_abs_dev_vs_loop": dev}
    return rows, record


def _bench_grid_ema(quick: bool = False):
    """table_ema grid: spec-driven reactive dedup ON vs OFF.

    A table_ema axis is dead for reactive (table-free) mechanisms, so the
    spec registry's exec_axes dedup collapses their rows to one class per
    point set (``run_grid(dedup=False)`` forces the old one-scan-per-point
    behavior). PC mechanisms keep one scan per point either way — the
    deltas below are pure reactive-row savings. Timings interleaved
    A/B/A/B per the bench-box protocol (2-core box, alternation cancels
    drift); min of each side reported.

    Returns (rows, record)."""
    import numpy as np
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import run_grid
    from repro.core.workloads import get_workload
    from benchmarks.paper_figs import WORKLOADS_FAST

    if quick:
        wls, mechs, n_ep, emas = WORKLOADS_FAST[:2], \
            ("crisp", "pcstall"), 60, [0.3, 0.5]
    else:
        wls, mechs, n_ep, emas = WORKLOADS_FAST[:6], \
            ("crisp", "accreac", "pcstall"), 200, [0.2, 0.5, 0.8]
    progs = {w: get_workload(w) for w in wls}
    # n_ep matches _bench_grid's scale on purpose: the executables are
    # shared with it, so this benchmark isolates dispatch-row savings
    # (the dedup wins rows, not compiles)
    cfg = SimConfig(n_epochs=n_ep)
    grid = {"table_ema": emas}

    def dedup_call():
        return run_grid(progs, cfg, grid, mechs)

    def full_call():
        return run_grid(progs, cfg, grid, mechs, dedup=False)

    SW.reset_counters()
    res_dedup = dedup_call()   # warm both sides before interleaving
    rows_dedup = sum(SW.DISPATCH_ROWS.values())
    SW.reset_counters()
    res_full = full_call()
    rows_full = sum(SW.DISPATCH_ROWS.values())

    reps = 2 if quick else 3
    full_t, dedup_t = [], []
    for _ in range(reps):
        full_t.append(_time_once(full_call))
        dedup_t.append(_time_once(dedup_call))
    full_s, dedup_s = min(full_t), min(dedup_t)

    # numerics: the broadcast class traces equal the per-point scans
    dev = 0.0
    for key, suite in res_full.items():
        for w in wls:
            for m in mechs:
                for k in suite[w][m]:
                    dev = max(dev, float(np.max(np.abs(
                        np.asarray(suite[w][m][k], np.float64)
                        - np.asarray(res_dedup[key][w][m][k], np.float64)))))

    g = len(emas)
    rows = [
        ("grid_ema_dedup", dedup_s * 1e6,
         f"{g}pt table_ema x {len(wls)}wl x {len(mechs)}mech x {n_ep}ep; "
         f"{rows_dedup} scan rows ({full_s / dedup_s:.2f}x vs no-dedup); "
         f"max|dev| {dev:.2g}"),
        ("grid_ema_full", full_s * 1e6,
         f"dedup=False: {rows_full} scan rows (one per mech x point)"),
    ]
    record = {"workloads": wls, "mechanisms": list(mechs), "n_epochs": n_ep,
              "table_ema_points": g,
              "dedup_warm_s": dedup_s, "full_warm_s": full_s,
              "speedup_warm": full_s / dedup_s,
              "scan_rows_dedup": rows_dedup, "scan_rows_full": rows_full,
              "max_abs_dev": dev}
    return rows, record


def _bench_grid_ivr(quick: bool = False):
    """IVR-regime grid (power x epoch_us) through ONE ``run_grid``
    dispatch vs a per-point ``run_suite`` loop.

    The ``power`` axis carries whole traced hardware regimes (V/f ladder
    endpoints + the transition-latency model), so a 3-regime x 2-epoch
    sensitivity figure compiles <= 2 fork-family executables total — the
    loop pays one dispatch per point (it reuses the same executables; the
    win is batching + fewer dispatches). NOTE the dedup angle: statics
    are LIVE in the power axes (ladder + energy accounting), and
    epoch_us is live for everything, so on this (power x epoch) grid no
    mechanism has a dead axis — the static row count recorded here is
    one scan per grid point, evidence that a swept hardware regime never
    silently collapses. Timings interleaved A/B/A/B per the bench-box
    protocol (2-core box, alternation cancels drift); min of each side
    reported.

    Returns (rows, record)."""
    import dataclasses

    import numpy as np
    from repro.core import power as PWR
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import run_grid, run_suite
    from repro.core.workloads import get_workload
    from benchmarks.paper_figs import WORKLOADS_FAST

    # n_ep distinct from every other bench scale (60/80/100/150/200/400)
    # so neither side can reuse executables another benchmark compiled
    if quick:
        wls, mechs, n_ep = WORKLOADS_FAST[:2], ("static17", "pcstall"), 70
        regimes, epochs = [PWR.PowerConfig(),
                           PWR.PowerConfig(lat_per_us=4e-1)], [1.0]
    else:
        wls, mechs, n_ep = WORKLOADS_FAST[:4], \
            ("static17", "crisp", "pcstall", "oracle"), 250
        regimes = [PWR.PowerConfig(),                 # 4ns @ 1us (paper)
                   PWR.PowerConfig(lat_per_us=4e-2),  # 40ns @ 1us
                   PWR.PowerConfig(lat_per_us=4e-1)]  # 400ns @ 1us
        epochs = [1.0, 10.0]
    progs = {w: get_workload(w) for w in wls}
    cfg = SimConfig(n_epochs=n_ep)
    grid = {"power": regimes, "epoch_us": epochs}
    axis_names, points = SW._grid_points(grid)

    def loop_points():
        return {tuple(p[n] for n in axis_names):
                run_suite(progs, dataclasses.replace(cfg, **p), mechs)
                for p in points}

    def grid_call():
        return run_grid(progs, cfg, grid, mechs)

    SW.reset_counters()
    t0 = time.perf_counter()
    res_grid = grid_call()
    grid_cold_s = time.perf_counter() - t0
    fork_compiles = sum(v for k, v in SW.TRACE_COUNTS.items()
                        if k in ("grid_forks", "grid_oracle"))
    static_rows = sum(v for k, v in SW.DISPATCH_ROWS.items()
                      if k.startswith("grid_static"))
    t0 = time.perf_counter()
    res_loop = loop_points()
    loop_cold_s = time.perf_counter() - t0

    reps = 2 if quick else 3
    loop_t, grid_t = [], []
    for _ in range(reps):
        loop_t.append(_time_once(loop_points))
        grid_t.append(_time_once(grid_call))
    loop_s, grid_s = min(loop_t), min(grid_t)

    # numerics: grid output vs the per-point suite loop (same executable
    # family -> bitwise)
    dev = 0.0
    for key, suite in res_loop.items():
        for w in wls:
            for m in mechs:
                for k in suite[w][m]:
                    dev = max(dev, float(np.max(np.abs(
                        np.asarray(suite[w][m][k], np.float64)
                        - np.asarray(res_grid[key][w][m][k], np.float64)))))

    g = len(points)
    rows = [
        ("grid_ivr_total", grid_cold_s * 1e6,
         f"{g}pt (power x epoch) x {len(wls)}wl x {len(mechs)}mech x "
         f"{n_ep}ep run_grid cold ({loop_cold_s / grid_cold_s:.1f}x); "
         f"{fork_compiles} fork-family compiles; static rows "
         f"{static_rows} — one per (power x epoch) point: statics are "
         "live in power, nothing collapses on this grid"),
        ("grid_ivr_warm", grid_s * 1e6,
         f"run_grid jit-cache hit ({loop_s / grid_s:.1f}x vs warm loop); "
         f"max|dev| vs loop {dev:.2g}"),
        ("grid_ivr_loop_cold", loop_cold_s * 1e6, "per-point run_suite loop"),
        ("grid_ivr_loop_warm", loop_s * 1e6, "per-point loop, jit-cached"),
    ]
    record = {"workloads": wls, "mechanisms": list(mechs), "n_epochs": n_ep,
              "grid_points": g, "power_regimes": len(regimes),
              "loop_cold_s": loop_cold_s, "grid_cold_s": grid_cold_s,
              "loop_warm_s": loop_s, "grid_warm_s": grid_s,
              "speedup_cold": loop_cold_s / grid_cold_s,
              "speedup_warm": loop_s / grid_s,
              "fork_family_compiles": fork_compiles,
              "static_mech_rows": static_rows,
              "max_abs_dev_vs_loop": dev}
    return rows, record


# run in a fresh interpreter per forced device count (XLA_FLAGS must be
# set before the first jax import); prints one JSON line on stdout
_SERVE_ARM_CODE = """
import json, sys, time
from repro.core import sweep as SW
from repro.core.simulate import SimConfig
from repro.data.pipeline import dvfs_request_stream
from repro.dvfs_runtime.service import DVFSService
import jax

p = json.loads(sys.argv[1])
sim = SimConfig(n_cu=p["n_cu"], n_wf=p["n_wf"], n_epochs=p["n_epochs"])
reqs = [(prog, ax) for prog, ax, _ in
        dvfs_request_stream(p["n_requests"], seed=7)]
svc = DVFSService(sim, max_batch=p["max_batch"], coalesce_s=0.001,
                  with_reports=False)
with svc:
    for f in [svc.submit(pr, ax) for pr, ax in reqs[:p["max_batch"]]]:
        f.result()                       # warm: compile the bucket shape
    svc.reset_stats()
    for f in [svc.submit(pr, ax) for pr, ax in reqs]:
        f.result()
    st = svc.stats()
fork = sum(v for k, v in SW.TRACE_COUNTS.items()
           if k in ("grid_forks", "grid_oracle"))
print(json.dumps({"n_dev": jax.local_device_count(),
                  "jobs_per_sec": st["jobs_per_sec"],
                  "p99_latency_s": st["p99_latency_s"],
                  "fork_family_compiles": fork}))
"""


def _serve_stream_arm(n_dev: int, params: dict) -> dict:
    """One forced-device-count serve_stream measurement in a subprocess
    (device count is fixed at first jax import, so each arm needs its own
    interpreter). Arms run sequentially per the bench-box protocol."""
    import os
    import subprocess
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, "-c", _SERVE_ARM_CODE, json.dumps(params)],
        capture_output=True, text=True, cwd=root, env=env, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _bench_serve_stream(quick: bool = False):
    """Streaming DVFS service: async micro-batched dispatch vs a per-job
    one-shot ``run_grid`` loop, at equal per-job work.

    The streamed side submits the whole trace-driven request stream
    (``data.pipeline.dvfs_request_stream``) to a live ``DVFSService`` and
    reports sustained jobs/sec + dispatch-latency percentiles from the
    service's own counters; the one-shot side dispatches the same jobs
    one batch-1 ``GridExecutor`` call each (jit-cached — the seed-style
    consumer a service replaces; the executor's 2-row bucket floor makes
    even these singleton dispatches bitwise against the streamed
    micro-batches). Timings interleaved A/B/A/B per the bench-box
    protocol; min of each side reported. The whole stream must compile
    <= 2 fork-family executables (asserted via TRACE_COUNTS) and every
    streamed row must equal the one-shot answer bitwise (asserted).

    Device scaling is reported two ways, honestly: (a) wall-clock
    jobs/sec from subprocess arms under forced 1- and 2-device host
    meshes (full mode only — meaningful only with >= 2 CPU cores: forced
    host devices on a 1-core box serialize, so wall clock CANNOT scale
    there and the record says so via the ``cores`` field); (b) the
    equal-per-job-work scaling T1(B)/T1(B/2) measured in-process — the
    per-batch speedup a 2-device mesh realizes when each device takes
    half the rows, which is what the >= 1.5x acceptance target means at
    equal per-job work.

    Returns (rows, record)."""
    import os

    import numpy as np
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import GridExecutor, run_grid
    from repro.data.pipeline import dvfs_request_stream
    from repro.dvfs_runtime.service import DVFSService

    # n_epochs distinct from every other bench scale so the stream pays
    # (and counts) its own compiles
    if quick:
        n_req, max_batch, n_ep = 8, 4, 50
    else:
        n_req, max_batch, n_ep = 48, 8, 300
    sim = SimConfig(n_cu=16, n_wf=12, n_epochs=n_ep)
    mechs = ("static17", "pcstall")
    reqs = [(prog, ax) for prog, ax, _ in dvfs_request_stream(n_req, seed=7)]

    before = dict(SW.TRACE_COUNTS)
    svc = DVFSService(sim, mechanism="pcstall", baseline="static17",
                      max_batch=max_batch, coalesce_s=0.001,
                      with_reports=False)

    def stream_pass():
        futs = [svc.submit(prog, ax) for prog, ax in reqs]
        return [f.result() for f in futs]

    results = stream_pass()  # cold: compiles the bucket shape
    fork_compiles = sum(SW.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
                        for k in ("grid_forks", "grid_oracle"))
    assert fork_compiles <= 2, \
        f"stream compiled {fork_compiles} fork-family executables"

    # acceptance: streamed rows == THE one-shot run_grid answer for the
    # same jobs, bitwise (one grid over the stream's workloads x its
    # distinct operating points; the per-job loop below routes through a
    # batch-1 GridExecutor, whose 2-row bucket floor keeps singleton
    # dispatches on the multi-row codegen — so THAT side is bitwise too,
    # asserted below, where it used to be recorded as a last-ulp max|dev|)
    points, progs_by_name = [], {}
    for prog, ax in reqs:
        if ax not in points:
            points.append(ax)
        progs_by_name[prog.name] = prog
    oneshot_grid = run_grid(list(progs_by_name.values()), sim, points, mechs)
    axis_names = list(points[0])
    for (prog, ax), res in zip(reqs, results):
        ref = oneshot_grid[tuple(ax[k] for k in axis_names)][prog.name]
        for m in mechs:
            for ch, v in ref[m].items():
                np.testing.assert_array_equal(
                    np.asarray(res["traces"][m][ch]), np.asarray(v),
                    err_msg=f"{prog.name}/{ax}/{m}/{ch}")

    ex1 = GridExecutor(sim, mechs)  # buckets=None: flat per-job dispatch

    def oneshot_pass():
        return [ex1.run([(prog, ax)])[0] for prog, ax in reqs]

    oneshot = oneshot_pass()  # cold: per-request one-shot dispatch
    # the executor's 2-row bucket floor keeps these batch-1 dispatches on
    # the same codegen as the streamed micro-batches, so the comparison
    # is exact — an assert, not a recorded deviation
    for (prog, ax), res, ref in zip(reqs, results, oneshot):
        for m in mechs:
            for ch, v in ref[m].items():
                np.testing.assert_array_equal(
                    np.asarray(res["traces"][m][ch]), np.asarray(v),
                    err_msg=f"perjob/{prog.name}/{ax}/{m}/{ch}")

    reps = 2 if quick else 3
    one_t, stream_stats = [], []
    for _ in range(reps):
        one_t.append(_time_once(oneshot_pass))
        svc.reset_stats()
        stream_pass()
        stream_stats.append(svc.stats())
    svc.close()
    oneshot_s = min(one_t)
    st = max(stream_stats, key=lambda s: s["jobs_per_sec"])
    oneshot_jps = n_req / oneshot_s

    # equal-per-job-work device scaling: one dispatch of B rows vs B/2
    # rows on this process's mesh — T1(B)/T1(B/2) is the per-batch
    # speedup a 2-device mesh realizes at half the rows per device
    ex = GridExecutor(sim, mechs, buckets=(max_batch // 2, max_batch))
    full_jobs, half_jobs = reqs[:max_batch], reqs[:max_batch // 2]
    ex.run(full_jobs), ex.run(half_jobs)  # warm both shapes
    full_t, half_t = [], []
    for _ in range(reps + 1):
        full_t.append(_time_once(lambda: ex.run(full_jobs)))
        half_t.append(_time_once(lambda: ex.run(half_jobs)))
    scaling = min(full_t) / min(half_t)

    cores = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    record = {
        "n_requests": n_req, "max_batch": max_batch, "n_epochs": n_ep,
        "mechanisms": list(mechs), "cores": cores,
        "jobs_per_sec": st["jobs_per_sec"],
        "p50_dispatch_latency_s": st["p50_latency_s"],
        "p99_dispatch_latency_s": st["p99_latency_s"],
        "mean_batch": st["mean_batch"],
        "oneshot_loop_jobs_per_sec": oneshot_jps,
        "speedup_stream_vs_oneshot": st["jobs_per_sec"] / oneshot_jps,
        "fork_family_compiles_stream": fork_compiles,
        "bitwise_vs_oneshot_run_grid": True,  # asserted above
        "bitwise_vs_perjob_executor_loop": True,  # asserted above
        "equal_work_scaling_T1B_over_T1halfB": scaling,
    }
    rows = [
        ("serve_stream", st["jobs_per_sec"],
         f"jobs/sec sustained ({n_req}req batch<= {max_batch} x "
         f"{len(mechs)}mech x {n_ep}ep; p99 {st['p99_latency_s'] * 1e3:.0f}ms; "
         f"{fork_compiles} fork-family compiles; bitwise vs one-shot)"),
        ("serve_stream_oneshot_loop", oneshot_jps,
         f"jobs/sec per-job batch-1 executor loop "
         f"({st['jobs_per_sec'] / oneshot_jps:.2f}x slower than stream; "
         "bitwise vs stream)"),
        ("serve_stream_equal_work_scaling", scaling,
         f"T1({max_batch})/T1({max_batch // 2}): per-batch speedup of a "
         "2-device mesh at half rows/device, at equal per-job work"),
    ]

    if not quick:
        params = {"n_cu": 16, "n_wf": 12, "n_epochs": n_ep,
                  "n_requests": 24, "max_batch": max_batch}
        arms = {n: _serve_stream_arm(n, params) for n in (1, 2)}
        ratio = arms[2]["jobs_per_sec"] / arms[1]["jobs_per_sec"]
        record["forced_1dev"] = arms[1]
        record["forced_2dev"] = arms[2]
        record["jobs_per_sec_2dev_over_1dev_wall"] = ratio
        record["note"] = (
            f"wall-clock 2dev/1dev ratio measured on a {cores}-core box; "
            "forced host devices share physical cores, so with cores < 2 "
            "the partitions serialize and wall clock cannot scale — "
            "equal_work_scaling_T1B_over_T1halfB is the per-batch speedup "
            "a real 2-device mesh realizes at half rows per device")
        rows.append(
            ("serve_stream_2dev_vs_1dev_wall", ratio,
             f"jobs/sec ratio, forced 2-dev vs 1-dev subprocess arms "
             f"({cores}-core box; see BENCH note)"))
    return rows, record


def _bench_learn(quick: bool = False):
    """Learned-predictor pipeline: the run_grid labeled-data factory, the
    AdamW fit, and frozen-spec deployment through the unmodified grid
    dispatch.

    Two records. ``learn_train``: dataset-factory wall time and rows/s,
    the jit train step's compile vs steady time, and the fit's final/val
    losses plus offline frequency-choice accuracy against the reactive
    baseline on the val split. ``learn_eval``: deployed per-epoch
    frequency-choice agreement with oracle on workloads HELD OUT from
    training (learned heads vs the crisp reactive baseline and PCSTALL),
    prediction-accuracy delta vs PCSTALL, ED2P vs static 1.7, and
    interleaved A/B/A/B dispatch timings (min per side, bench-box
    protocol) of the learned spec against the builtin pcstall it rides
    beside — the ParamHook path must not tax the grid dispatch. The
    learned spec's fork-compile bound and dedup row accounting are
    asserted, not assumed. Returns (rows, (train_record, eval_record))."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import sweep as SW
    from repro.core.simulate import SimConfig
    from repro.core.sweep import run_suite, suite_metrics
    from repro.core.workloads import get_workload
    from repro.learn import dataset as LDS
    from repro.learn import mechanism as LMECH
    from repro.learn import models as LM
    from repro.learn import train as LTR

    if quick:
        dcfg = LDS.DatasetConfig(workloads=("comd", "xsbench"), seeds=(0,),
                                 epoch_us=(1.0,), n_cu=8, n_epochs=64,
                                 warmup=8, val_frac=0.5)
        steps, kinds, eval_wls, eval_ep, reps = \
            40, ("linear",), ["lulesh", "hacc"], 64, 2
    else:
        dcfg = LDS.DatasetConfig()
        steps, kinds, eval_wls, eval_ep, reps = \
            400, ("linear", "mlp"), \
            ["quickS", "snapc", "BwdBN", "FwdSoft"], 300, 3
    rows = []

    t0 = time.perf_counter()
    data, meta = LDS.generate_dataset(dcfg)
    t_data = time.perf_counter() - t0
    n_rows = int(data["x"].shape[0])
    rows.append(("learn_dataset_factory", t_data * 1e6,
                 f"us total ({n_rows / t_data:.0f} labeled rows/s via "
                 "run_grid)"))
    _, val_mask = LDS.split_masks(data)
    train_rec = {"rows": n_rows, "runs": len(meta["runs"]),
                 "dataset_factory_s": t_data,
                 "rows_per_s": n_rows / t_data,
                 "reactive_choice_acc_val":
                     LTR.reactive_choice_baseline(data, meta, val_mask)}

    # jit train-step micro: compile once vs steady (train_step idiom)
    tc = LTR.default_tc("linear", steps)
    step_fn, _ = LTR.make_train_step(
        "linear", tc, np.zeros(2, np.float32), np.ones(2, np.float32))
    p0 = LM.init_linear(0)
    from repro.optim import adamw
    state = {"params": jax.tree.map(jnp.asarray, p0),
             "opt": adamw.init(p0), "step": jnp.zeros((), jnp.int32)}
    bs = min(4096, n_rows)
    batch = {"x": jnp.asarray(data["x"][:bs]),
             "react": jnp.asarray(data["x"][:bs, list(LM.REACT_COLS)]),
             "y": jnp.asarray(data["y"][:bs])}

    def one_step():
        nonlocal state
        state, m = step_fn(state, dict(batch))
        jax.block_until_ready(m["loss"])
    t_compile = _time_once(one_step)
    t_step = min(_time_once(one_step) for _ in range(5))
    rows.append(("learn_train_step_compile", t_compile * 1e6,
                 "us first call (trace+compile; paid once)"))
    rows.append(("learn_train_step", t_step * 1e6,
                 f"us/step steady-state (batch {bs})"))
    train_rec.update(step_compile_ms=t_compile * 1e3,
                     step_us=t_step * 1e6, batch_size=bs)

    specs = {}
    for kind in kinds:
        t0 = time.perf_counter()
        params, curves = LTR.fit(data, meta, kind=kind, steps=steps)
        t_fit = time.perf_counter() - t0
        name = "learned_lin" if kind == "linear" else "learned_mlp"
        specs[name] = LMECH.make_learned_spec(name, params)
        train_rec[name] = {
            "fit_s": t_fit, "steps": steps,
            "final_loss": curves["probe"][-1],
            "first_loss": curves["probe"][0],
            "val_mse": curves.get("val_mse"),
            "val_choice_acc": curves.get("val_choice_acc")}
        rows.append((f"learn_fit_{kind}", t_fit * 1e6,
                     f"us for {steps} AdamW steps (probe loss "
                     f"{curves['probe'][0]:.3f}->{curves['probe'][-1]:.3f})"))

    # --- deployment eval on held-out workloads --------------------------
    mechs = ("static17", "crisp", "pcstall", *specs.values(), "oracle")
    progs = {w: get_workload(w) for w in eval_wls}
    sim = SimConfig(n_cu=dcfg.n_cu, n_epochs=eval_ep,
                    objective=dcfg.objective)
    SW.reset_counters()
    grid = run_suite(progs, sim, mechs)
    fork_compiles = sum(SW.TRACE_COUNTS.get(k, 0)
                        for k in ("grid_forks", "grid_oracle"))
    assert fork_compiles <= 2, dict(SW.TRACE_COUNTS)
    for name in specs:
        assert SW.DISPATCH_ROWS[f"grid_{name}"] == len(eval_wls), \
            dict(SW.DISPATCH_ROWS)

    warm = min(50, eval_ep // 4)
    agree = {m: float(np.mean([np.mean(
        grid[w][m]["fidx"][warm:] == grid[w]["oracle"]["fidx"][warm:])
        for w in eval_wls]))
        for m in ("crisp", "pcstall", *specs)}
    r = suite_metrics(None, sim, mechs, n=2, traces=grid)
    gm = {m: float(np.exp(np.mean([np.log(r[w][m]["ednp_norm"])
                                   for w in eval_wls])))
          for m in ("crisp", "pcstall", *specs, "oracle")}
    acc = {m: float(np.mean([r[w][m]["accuracy"] for w in eval_wls]))
           for m in ("crisp", "pcstall", *specs)}
    eval_rec = {"workloads_heldout": eval_wls,
                "held_out_of_training": [w for w in eval_wls
                                         if w not in dcfg.workloads],
                "choice_agreement_vs_oracle": agree,
                "accuracy": acc,
                "accuracy_delta_vs_pcstall": {
                    m: acc[m] - acc["pcstall"] for m in specs},
                "ed2p_vs_static17": gm,
                "fork_family_compiles": fork_compiles}
    for name in specs:
        rows.append((f"learn_eval_{name}", 0.0,
                     f"choice-agreement {agree[name]:.3f} vs reactive "
                     f"{agree['crisp']:.3f} (heldout); ED2P {gm[name]:.3f}"))

    # --- interleaved A/B: learned spec vs builtin pcstall dispatch ------
    spec_lin = specs["learned_lin"]

    def side_a():
        run_suite(progs, sim, (spec_lin,))

    def side_b():
        run_suite(progs, sim, ("pcstall",))
    side_a(), side_b()  # both warm
    ta, tb = [], []
    for _ in range(reps):
        ta.append(_time_once(side_a))
        tb.append(_time_once(side_b))
    eval_rec["dispatch_s_learned_lin"] = min(ta)
    eval_rec["dispatch_s_pcstall"] = min(tb)
    eval_rec["dispatch_overhead_vs_pcstall"] = min(ta) / min(tb)
    rows.append(("learn_dispatch_learned_lin", min(ta) * 1e6,
                 f"us/suite interleaved ({min(ta) / min(tb):.2f}x pcstall)"))
    rows.append(("learn_dispatch_pcstall", min(tb) * 1e6,
                 "us/suite interleaved baseline"))
    return rows, (train_rec, eval_rec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--figs", default=None,
                    help="comma list of figure names, 'all', or 'none' "
                         "(default: all, or none with --quick)")
    ap.add_argument("--skip-micros", action="store_true")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="skip the run_suite-vs-serial sweep benchmark")
    ap.add_argument("--skip-grid", action="store_true",
                    help="skip the run_grid-vs-per-point-loop benchmark")
    ap.add_argument("--skip-serve", action="store_true",
                    help="skip the streaming-service benchmark")
    ap.add_argument("--skip-learn", action="store_true",
                    help="skip the learned-predictor pipeline benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: tiny sweep, no figures, <=30s")
    args = ap.parse_args()
    figs = args.figs if args.figs is not None else \
        ("none" if args.quick else "all")

    print("name,us_per_call,derived")
    bench: dict = {"quick": args.quick}
    if not args.skip_micros:
        rows, bench["sim_epoch_pcstall_64cu"] = _perf_micros(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows, bench["kernel_epoch"] = _bench_kernel_epoch(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows, bench["grid_kernel"] = _bench_grid_kernel(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    if not args.skip_sweep:
        rows, bench["sweep_fig15_total"] = _bench_sweep(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    if not args.skip_grid:
        rows, bench["grid_2x2"] = _bench_grid(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows, bench["grid_ema"] = _bench_grid_ema(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows, bench["grid_ivr"] = _bench_grid_ivr(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    if not args.skip_serve:
        rows, bench["serve_stream"] = _bench_serve_stream(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    if not args.skip_learn:
        rows, (bench["learn_train"], bench["learn_eval"]) = \
            _bench_learn(args.quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
    if len(bench) > 1:
        if args.quick:
            # never clobber the full-scale perf trajectory with smoke numbers
            out = BENCH_JSON.with_name("BENCH_sweep_quick.json")
        else:
            out = BENCH_JSON
        # merge so a partial run (--skip-sweep/--skip-micros) doesn't drop
        # the other benchmark's record from the perf trajectory
        if out.exists():
            try:
                prev = json.loads(out.read_text())
            except json.JSONDecodeError:
                prev = {}
            bench = {**prev, **bench}
        out.write_text(json.dumps(bench, indent=1))
        print(f"# wrote {out}")

    from benchmarks.paper_figs import ALL_FIGS
    names = (list(ALL_FIGS) if figs == "all"
             else [] if figs == "none" else figs.split(","))
    for name in names:
        t0 = time.perf_counter()
        res = ALL_FIGS[name]()
        dt = (time.perf_counter() - t0) * 1e6
        # one-line derived summary per figure
        if name == "fig14_accuracy":
            d = res["MEAN"]
            summary = " ".join(f"{m}={d[m]:.2f}" for m in
                               ("crisp", "accreac", "pcstall", "accpc", "oracle"))
        elif name == "fig15_ed2p":
            d = res["GEOMEAN"]
            summary = " ".join(f"{m}={d[m]:.2f}" for m in
                               ("static22", "crisp", "pcstall", "oracle"))
        elif name == "fig01_epoch_sweep":
            summary = " ".join(f"{T}us:pc={v['ed2p']['pcstall']:.2f}/or={v['ed2p']['oracle']:.2f}"
                               for T, v in res.items())
        elif name == "fig07_variation":
            summary = " ".join(f"{T}us={v:.2f}" for T, v in res["epoch_sweep"].items())
        elif name == "fig10_pc_stability":
            summary = f"mean_samePC_var={res['MEAN']:.3f}"
        elif name == "fig11b_offset_sweep":
            summary = " ".join(f"{k}={v:.2f}" for k, v in res.items())
        elif name == "fig18a_energy_caps":
            summary = " ".join(f"{o}:pc={v['pcstall']:.3f}" for o, v in res.items())
        elif name == "fig18b_granularity":
            summary = " ".join(f"{g}:pc={v['pcstall']:.2f}" for g, v in res.items())
        elif name == "fig_ivr_regime":
            summary = " ".join(
                f"{k}:pc={v['pcstall']:.2f}" for k, v in res.items()
                if isinstance(v, dict) and "pcstall" in v and "@1us" in k)
            summary += " finest_paying=" + ",".join(
                f"{r}:{T}" for r, T in res["finest_paying_epoch_us"].items())
        elif name == "fig_learned":
            d = res["choice_agreement_heldout"]
            summary = "heldout-agree " + " ".join(
                f"{m}={d[m]:.2f}" for m in
                ("crisp", "pcstall", "learned_lin", "learned_mlp")) + \
                " ed2p lin=" + \
                f"{res['ed2p_geomean']['learned_lin']:.2f}" + \
                f" mlp={res['ed2p_geomean']['learned_mlp']:.2f}"
        else:
            summary = "ok"
        print(f"{name},{dt:.0f},{summary}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
