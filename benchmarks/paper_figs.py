"""One function per paper table/figure. Results cached to experiments/results/.

All multi-(workload x mechanism) figures dispatch through the batched sweep
layer — and there is only ONE dispatch path: single-point figures call
``run_suite`` (literally a 1-point ``run_grid``), and every figure whose
grid spans traced SimConfig axes — epoch granularity (fig01/07), objective
(fig18a) — calls ``run_grid`` directly, which runs the whole grid as one
device-sharded executable family instead of one dispatch per grid point
(static-frequency mechanisms additionally scan once per execution class,
not once per objective point). Only fig18b still loops in Python: its
V/f-domain-granularity axis reshapes arrays and so is a static (shape)
axis by design.

Figures:
  fig01a  ED2P opportunity vs DVFS epoch duration
  fig01b  prediction accuracy vs epoch duration
  fig07   consecutive-epoch sensitivity variation (1us + epoch sweep)
  fig10   same-PC iteration variation at WF/CU/64CU granularity
  fig11b  PC-table index offset sweep
  fig14   prediction accuracy by mechanism
  fig15   ED2P by workload, normalized to static 1.7 GHz
  fig16   frequency time-share under PCSTALL
  fig17   EDP vs epoch duration
  fig18a  energy savings at 5%/10% perf-degradation caps
  fig18b  ED2P vs V/f-domain granularity
  tab01   hardware table overhead
  fig_ivr_regime  ED2P vs IVR transition-latency regime x epoch length
                  (the power axis: one run_grid over PowerConfig points)
  fig_learned     learned predictors (trained on oracle traces) vs
                  PCSTALL vs reactive vs oracle over all Table II
                  workloads, half of them held out from training
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core import mechanisms as MECH
from repro.core.simulate import (SimConfig, ednp, prediction_accuracy,
                                 run_sim)
from repro.core.sweep import run_grid, run_suite, suite_metrics
from repro.core.workloads import get_workload

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "results"
RESULTS.mkdir(parents=True, exist_ok=True)

# the figure suites come from the MechanismSpec registry: the full paper
# family, and the fast subset that drops the three slowest-to-separate
# CU-reactive baselines (kept: the best reactive, CRISP, and the
# fork-accurate ACCREAC)
CORE_MECHS = MECH.BUILTIN_NAMES
FAST_MECHS = tuple(m for m in CORE_MECHS if m not in ("stall", "lead",
                                                      "crit"))
N_EPOCHS = 800


def _cache(name: str, fn):
    f = RESULTS / f"{name}.json"
    if f.exists():
        return json.loads(f.read_text())
    out = fn()
    f.write_text(json.dumps(out, indent=1))
    return out


def _consec_var(s: np.ndarray) -> float:
    sbar = np.maximum(np.mean(s, axis=0, keepdims=True), 1e-6)
    return float(np.mean(np.abs(np.diff(s, axis=0)) / sbar))


WORKLOADS_FAST = ["comd", "hpgmg", "lulesh", "xsbench", "hacc", "quickS",
                  "dgemm", "BwdBN", "BwdPool", "FwdSoft"]


def _progs(names: List[str]) -> Dict:
    return {w: get_workload(w) for w in names}


def fig14_accuracy() -> Dict:
    """Prediction accuracy by mechanism (paper Fig 14)."""
    def run():
        mechs = tuple(m for m in CORE_MECHS
                      if MECH.get(m).family != "static")
        # single-point grid: same sharded dispatch path as the sweeps
        traces = run_grid(_progs(WORKLOADS_FAST), SimConfig(n_epochs=N_EPOCHS),
                          {"epoch_us": [1.0]}, mechs)[(1.0,)]
        out = {wl: {m: prediction_accuracy(trs[m]) for m in mechs}
               for wl, trs in traces.items()}
        out["MEAN"] = {m: float(np.mean([out[w][m] for w in WORKLOADS_FAST]))
                       for m in mechs}
        return out
    return _cache("fig14_accuracy", run)


def fig15_ed2p() -> Dict:
    """ED2P by workload normalized to static 1.7 GHz (paper Fig 15)."""
    def run():
        sim = SimConfig(n_epochs=N_EPOCHS)
        traces = run_grid(_progs(WORKLOADS_FAST), sim,
                          {"epoch_us": [1.0]}, FAST_MECHS)[(1.0,)]
        r = suite_metrics(None, sim, FAST_MECHS, n=2, traces=traces)
        out = {wl: {m: float(d["ednp_norm"]) for m, d in r[wl].items()}
               for wl in WORKLOADS_FAST}
        out["GEOMEAN"] = {m: float(np.exp(np.mean([np.log(out[w][m])
                          for w in WORKLOADS_FAST]))) for m in FAST_MECHS}
        return out
    return _cache("fig15_ed2p", run)


def fig01_epoch_sweep() -> Dict:
    """ED2P opportunity + accuracy vs epoch duration (paper Fig 1a/1b, 17).

    The whole epoch-granularity grid (with its coupled logical epoch
    counts) runs as one ``run_grid`` executable family; the same traces
    feed both the n=2 (ED2P) and n=1 (EDP) metrics."""
    def run():
        mechs = ("static17", "crisp", "pcstall", "oracle")
        wls = ["comd", "hacc", "lulesh", "dgemm", "xsbench", "BwdBN"]
        cfg = SimConfig()
        points = [{"epoch_us": T,
                   "n_epochs": max(200, int(1200 / max(T / 4, 1)))}
                  for T in (1.0, 10.0, 50.0, 100.0)]
        # n_epochs is strongly coupled to epoch_us here (1200 at 1us vs
        # 200 at 100us): bound the masked-tail waste by bucketing
        grid = run_grid(_progs(wls), cfg, points, mechs, max_mask_ratio=2.0)
        out = {}
        for pt in points:
            sim = dataclasses.replace(cfg, **pt)
            traces = grid[(pt["epoch_us"], pt["n_epochs"])]
            r2 = suite_metrics(None, sim, mechs, n=2, traces=traces)
            r1 = suite_metrics(None, sim, mechs, n=1, traces=traces)
            out[str(pt["epoch_us"])] = {
                "ed2p": {m: float(np.exp(np.mean([np.log(r2[w][m]["ednp_norm"])
                         for w in wls]))) for m in mechs},
                "edp": {m: float(np.exp(np.mean([np.log(r1[w][m]["ednp_norm"])
                        for w in wls]))) for m in mechs},
                "accuracy": {m: float(np.mean([r2[w][m]["accuracy"]
                             for w in wls])) for m in mechs
                             if m != "static17"},
            }
        return out
    return _cache("fig01_epoch_sweep", run)


def fig07_variation() -> Dict:
    """Sensitivity variation across consecutive epochs (paper Fig 7a/7b)."""
    def run():
        out = {"per_workload_1us": {}, "epoch_sweep": {}}
        traces = run_suite(_progs(WORKLOADS_FAST), SimConfig(n_epochs=400),
                           ("accreac",))
        for wl in WORKLOADS_FAST:
            out["per_workload_1us"][wl] = _consec_var(
                traces[wl]["accreac"]["true_sens"][50:])
        wls = ["comd", "hacc", "dgemm", "xsbench"]
        Ts = (1.0, 10.0, 50.0, 100.0)
        grid = run_grid(_progs(wls), SimConfig(n_epochs=300),
                        {"epoch_us": list(Ts)}, ("accreac",))
        for T in Ts:
            out["epoch_sweep"][str(T)] = float(np.mean(
                [_consec_var(grid[(T,)][w]["accreac"]["true_sens"][30:])
                 for w in wls]))
        return out
    return _cache("fig07_variation", run)


def fig10_pc_stability() -> Dict:
    """Same-start-PC iteration variation (paper Fig 10) at WF granularity."""
    def run():
        wls = ["comd", "hacc", "dgemm", "xsbench", "lulesh"]
        traces = run_suite(_progs(wls),
                           SimConfig(n_epochs=500, record_wf=True),
                           ("accreac",))
        out = {}
        for wl in wls:
            tr = traces[wl]["accreac"]
            ws, wb = tr["wf_sens"][50:], tr["wf_blk"][50:]
            vals = []
            for cu in range(0, 64, 16):
                for wf in range(0, 40, 13):
                    sv, bv = ws[:, cu, wf], wb[:, cu, wf]
                    sm = max(float(np.mean(np.abs(sv))), 1e-6)
                    for b in np.unique(bv)[:15]:
                        x = sv[bv == b]
                        if len(x) > 2:
                            vals.append(np.mean(np.abs(np.diff(x)) / sm))
            out[wl] = float(np.mean(vals))
        out["MEAN"] = float(np.mean(list(out.values())))
        return out
    return _cache("fig10_pc_stability", run)


def fig11b_offset_sweep() -> Dict:
    """PC-table index offset sweep (paper Fig 11b)."""
    def run():
        wls = ["comd", "hacc", "lulesh", "BwdBN"]
        progs = _progs(wls)
        out = {}
        for off in (1, 2, 4, 8, 16, 32, 64):
            tr = run_suite(progs, SimConfig(n_epochs=500, offset_blocks=off),
                           ("pcstall",))
            out[str(off * 4) + "_instr"] = float(np.mean(
                [prediction_accuracy(tr[w]["pcstall"]) for w in wls]))
        return out
    return _cache("fig11b_offset_sweep", run)


def fig16_timeshare() -> Dict:
    """Frequency time-share per workload under PCSTALL/ED2P (paper Fig 16)."""
    def run():
        traces = run_suite(_progs(WORKLOADS_FAST),
                           SimConfig(n_epochs=N_EPOCHS), ("pcstall",))
        out = {}
        for wl in WORKLOADS_FAST:
            fidx = traces[wl]["pcstall"]["fidx"]
            h = np.bincount(fidx.ravel(), minlength=10) / fidx.size
            out[wl] = [round(float(x), 4) for x in h]
        return out
    return _cache("fig16_timeshare", run)


def fig18a_energy_caps() -> Dict:
    """Energy savings at perf-degradation caps (paper Fig 18a)."""
    def run():
        mechs = ("crisp", "pcstall", "accpc", "oracle")
        wls = ["comd", "hacc", "lulesh", "dgemm", "xsbench", "BwdBN"]
        progs = _progs(wls)
        cfg = SimConfig(n_epochs=N_EPOCHS)
        # every sweep dispatches through the one grid family, so this
        # baseline is bitwise-consistent with the traces it is divided
        # against by construction (run_suite would be the same executable)
        bases = run_grid(progs, cfg, {"epoch_us": [cfg.epoch_us]},
                         ("static22",))[(cfg.epoch_us,)]
        # both perf-cap objectives in one grid executable family
        grid = run_grid(progs, cfg,
                        {"objective": ["perfcap05", "perfcap10"]}, mechs)
        out = {}
        for obj in ("perfcap05", "perfcap10"):
            traces = grid[(obj,)]
            sub = {}
            for m in mechs:
                savings = []
                for wl in wls:
                    base = bases[wl]["static22"]
                    budget = 0.9 * base["work"].sum()
                    E0, _, _ = ednp(base, budget, cfg.epoch_us)
                    E, _, _ = ednp(traces[wl][m], budget, cfg.epoch_us)
                    savings.append(1.0 - E / E0)
                sub[m] = float(np.mean(savings))
            out[obj] = sub
        return out
    return _cache("fig18a_energy_caps", run)


def fig18b_granularity() -> Dict:
    """ED2P vs V/f-domain granularity (paper Fig 18b).

    The domain-size axis reshapes (CU -> domain) arrays, so it is a static
    shape axis: one executable family per granularity, looped in Python."""
    def run():
        mechs = ("crisp", "pcstall", "oracle")
        wls = ["comd", "hacc", "lulesh", "BwdBN"]
        progs = _progs(wls)
        out = {}
        for g in (1, 2, 4, 8, 16, 32):
            sim = SimConfig(n_epochs=N_EPOCHS, cus_per_domain=g,
                            cus_per_table=g)
            r = suite_metrics(progs, sim, mechs, n=2)
            out[str(g) + "CU"] = {
                m: float(np.exp(np.mean([np.log(r[w][m]["ednp_norm"])
                                         for w in wls]))) for m in mechs}
        return out
    return _cache("fig18b_granularity", run)


def fig_ivr_regime() -> Dict:
    """ED2P vs IVR transition-latency regime x epoch granularity.

    The paper's core hardware premise (§5, §1): IVR transition latency
    shrinking from the us range to ns (4ns @ 1us epochs) is what unlocks
    fine-grain DVFS at all. This sensitivity sweep makes the premise a
    figure: three latency regimes — the paper's on-chip IVR (4ns @ 1us)
    and 10x/100x slower regulators (40ns/400ns @ 1us) — crossed with
    epoch granularities from 1us to 100us, all through ``run_grid`` over
    the traced ``power`` axis (PowerConfig grid values; <= 2 fork-family
    compiles per n_epochs bucket — the masked-tail bucketing splits this
    coupled grid into two buckets). The crossover the table shows: with a
    slow regulator the 1us operating point inverts (fine-grain switching
    costs more than prediction buys, and the paper's predict-over-react
    advantage only survives at coarse epochs where reaction is nearly as
    good), while the ns-regime makes 1us epochs the best point and the
    predict-vs-react gap widest."""
    def run():
        from repro.core import power as PWR
        mechs = ("static17", "crisp", "pcstall", "oracle")
        wls = ["comd", "hacc", "lulesh", "xsbench"]
        regimes = {  # label = transition latency at 1us epochs
            "4ns": PWR.PowerConfig(),                   # paper on-chip IVR
            "40ns": PWR.PowerConfig(lat_per_us=4e-2),
            "400ns": PWR.PowerConfig(lat_per_us=4e-1),
        }
        epochs = [(1.0, 800), (10.0, 300), (100.0, 200)]
        points = [{"power": pw, "epoch_us": T, "n_epochs": n}
                  for pw in regimes.values() for (T, n) in epochs]
        cfg = SimConfig()
        grid = run_grid(_progs(wls), cfg, points, mechs, max_mask_ratio=3.0)
        out: Dict = {}
        for rname, pw in regimes.items():
            for T, n in epochs:
                sim = dataclasses.replace(cfg, power=pw, epoch_us=T,
                                          n_epochs=n)
                r = suite_metrics(None, sim, mechs, n=2,
                                  traces=grid[(pw, T, n)])
                out[f"{rname}@{T:g}us"] = {
                    m: float(np.exp(np.mean([np.log(r[w][m]["ednp_norm"])
                                             for w in wls]))) for m in mechs}
        # the headline crossover: the finest epoch at which predictive
        # fine-grain DVFS still beats the static baseline, per regime
        out["finest_paying_epoch_us"] = {
            rname: next((T for T, _ in epochs
                         if out[f"{rname}@{T:g}us"]["pcstall"] < 1.0),
                        None)
            for rname in regimes}
        return out
    return _cache("fig_ivr_regime", run)


def fig_learned() -> Dict:
    """Learned predictors vs PCSTALL vs reactive vs oracle (the ROADMAP's
    learned-predictor item; the Ilager et al. arXiv:2004.08177 line).

    Trains both heads on the ``repro.learn`` factory dataset — 8 of the
    16 Table II workloads x 2 seeds x {1, 10} us granularities, oracle
    choices as labels — freezes + registers them as ``family='pc'``
    specs, and deploys them over ALL 16 workloads at the training shape.
    The other 8 workloads never appear in training, so the
    ``*_heldout`` aggregates are honestly out-of-sample. Reports
    per-epoch frequency-choice agreement with the oracle's deployed
    trace (the predict-don't-react headline metric), prediction accuracy
    and its delta vs PCSTALL, and ED2P vs static 1.7."""
    def run():
        from repro.core.workloads import WORKLOAD_TABLE
        from repro.learn import dataset as LDS
        from repro.learn import mechanism as LMECH
        from repro.learn import train as LTR
        dcfg = LDS.DatasetConfig()
        data, meta = LDS.generate_dataset(dcfg)
        _, val_mask = LDS.split_masks(data)
        out: Dict = {"train": {
            "runs": len(meta["runs"]), "rows": int(data["x"].shape[0]),
            "reactive_choice_acc_val":
                LTR.reactive_choice_baseline(data, meta, val_mask)}}
        mechs = ["static17", "crisp", "pcstall"]
        learned = []
        for kind, steps, name in (("linear", 600, "learned_lin"),
                                  ("mlp", 900, "learned_mlp")):
            params, curves = LTR.fit(data, meta, kind=kind, steps=steps)
            LMECH.register_learned(name, params, allow_override=True)
            learned.append(name)
            mechs.append(name)
            out["train"][name] = {
                "first_loss": curves["probe"][0],
                "final_loss": curves["probe"][-1],
                "val_mse": curves.get("val_mse"),
                "val_choice_acc": curves.get("val_choice_acc")}
        mechs.append("oracle")
        try:
            wls = list(WORKLOAD_TABLE)
            sim = dataclasses.replace(dcfg.sim(), n_epochs=400)
            grid = run_suite(_progs(wls), sim, tuple(mechs))
            warm = 50
            agree = {m: {w: float(np.mean(
                grid[w][m]["fidx"][warm:] == grid[w]["oracle"]["fidx"][warm:]))
                for w in wls} for m in mechs if m != "oracle"}
            heldout = [w for w in wls if w not in dcfg.workloads]
            out["choice_agreement"] = agree
            out["choice_agreement_mean"] = {
                m: float(np.mean(list(v.values())))
                for m, v in agree.items()}
            out["choice_agreement_heldout"] = {
                m: float(np.mean([v[w] for w in heldout]))
                for m, v in agree.items()}
            r = suite_metrics(None, sim, tuple(mechs), n=2, traces=grid)
            gm = lambda m, ws: float(np.exp(np.mean(
                [np.log(r[w][m]["ednp_norm"]) for w in ws])))
            out["ed2p_geomean"] = {m: gm(m, wls) for m in mechs
                                   if m != "static17"}
            out["ed2p_geomean_heldout"] = {m: gm(m, heldout) for m in mechs
                                           if m != "static17"}
            acc = {m: float(np.mean([r[w][m]["accuracy"] for w in wls]))
                   for m in mechs if m != "static17"}
            out["accuracy_mean"] = acc
            out["accuracy_delta_vs_pcstall"] = {
                m: acc[m] - acc["pcstall"] for m in learned}
        finally:
            for name in learned:
                MECH.unregister(name)
        return out
    return _cache("fig_learned", run)


def tab01_overhead() -> Dict:
    """Hardware storage overhead of PCSTALL (paper Table I)."""
    entries, wf = 128, 40
    return {
        "sensitivity_table_bytes": entries,          # 1B quantized sens/entry
        "starting_pc_registers_bytes": wf,           # index bits only
        "stall_time_registers_bytes": 4 * wf,
        "total_bytes": entries + wf + 4 * wf,
        "note": "matches paper Table I: 328B per PCSTALL instance",
    }


ALL_FIGS = {
    "fig01_epoch_sweep": fig01_epoch_sweep,
    "fig07_variation": fig07_variation,
    "fig10_pc_stability": fig10_pc_stability,
    "fig11b_offset_sweep": fig11b_offset_sweep,
    "fig14_accuracy": fig14_accuracy,
    "fig15_ed2p": fig15_ed2p,
    "fig16_timeshare": fig16_timeshare,
    "fig18a_energy_caps": fig18a_energy_caps,
    "fig18b_granularity": fig18b_granularity,
    "fig_ivr_regime": fig_ivr_regime,
    "fig_learned": fig_learned,
    "tab01_overhead": tab01_overhead,
}


def fig11a_slot_contention() -> Dict:
    """Per-WF-slot sensitivity variation (paper Fig 11a, quickS): the
    oldest-first scheduler shields slot 0; younger slots vary more."""
    def run():
        # occupancy-saturated CU (paper's quickS is issue-bound): lower the
        # issue capacity so the oldest-first scheduler actually squeezes
        tr = run_sim(get_workload("quickS"),
                     SimConfig(n_epochs=500, record_wf=True,
                               cap_per_ghz=2400.0), "accreac")
        ws = tr["wf_sens"][50:]  # (T, CU, WF)
        out = []
        for k in range(0, 40, 4):
            sv = ws[:, :, k]
            sbar = np.maximum(np.mean(np.abs(sv), axis=0, keepdims=True), 1e-6)
            out.append(float(np.mean(np.abs(np.diff(sv, axis=0)) / sbar)))
        return {"slots_0_36_step4": out,
                "slope_positive": bool(out[-1] > out[0])}
    return _cache("fig11a_slot_contention", run)


def tab_hitrate() -> Dict:
    """PC-table hit ratio vs entries (paper §4.4: 128 entries -> 95%+)."""
    def run():
        wls = ["comd", "hacc", "lulesh", "dgemm"]
        progs = _progs(wls)
        out = {}
        for entries in (16, 32, 64, 128, 256):
            sim = SimConfig(n_epochs=400, entries=entries,
                            offset_blocks=max(1024 // entries, 1))
            tr = run_suite(progs, sim, ("pcstall",))
            out[str(entries)] = float(np.mean(
                [np.mean(tr[w]["pcstall"]["hit_rate"][50:]) for w in wls]))
        return out
    return _cache("tab_hitrate", run)


ALL_FIGS["fig11a_slot_contention"] = fig11a_slot_contention
ALL_FIGS["tab_hitrate"] = tab_hitrate
