"""Tests for the split power layer: the static/traced ``PowerStatic`` /
``PowerAxes`` halves of ``PowerConfig``, closed-form model values at swept
(non-default) hardware points, the traced V/f ladder, the IVR
transition-latency model, power-regime grids through ``run_grid``
(bitwise vs a per-point loop; ``DISPATCH_ROWS`` splitting on the power
axis — statics are LIVE in power, unlike objective/table_ema), the
default-regime bitwise contract against the captured reference, and the
IVR-regime acceptance grid (>=3 latency models x >=2 epoch lengths in
<=2 fork-family compiles)."""
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import power as PWR
from repro.core import sweep as SW
from repro.core.power import PowerAxes, PowerConfig, PowerStatic
from repro.core.simulate import SimConfig, run_sim
from repro.core.sweep import run_grid, run_suite
from repro.core.workloads import get_workload

WORKLOADS = ("comd", "xsbench")
# a decidedly non-default hardware point (wider V range, leakier, lossier
# IVR, slow off-chip-regulator latency model)
SWEPT = PowerConfig(v_min=0.60, v_max=1.10, f_min=1.0, f_max=2.0,
                    c_eff=1.3, k_leak=0.5, eta0=0.88, eta_slope=-0.08,
                    c_trans=0.02, lat_per_us=4e-2, lat_cap_us=0.8)


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


# ---------------------------------------------------------------------------
# The split + closed-form model values at swept parameters
# ---------------------------------------------------------------------------


def test_static_axes_split_roundtrip():
    pw = SWEPT
    assert pw.static_part() == PowerStatic(n_freqs=10)
    ax = pw.axes()
    assert isinstance(ax, PowerAxes)
    for f in PowerAxes._fields:
        v = getattr(ax, f)
        assert v.dtype == jnp.float32 and v.shape == ()
        assert float(v) == pytest.approx(getattr(pw, f)), f
    # the ladder length is the static (shape) half and >= 2 by contract
    assert PowerConfig(n_freqs=6).static_part().n_freqs == 6
    with pytest.raises(AssertionError, match="ladder"):
        PowerStatic(n_freqs=1)


def test_v_of_f_closed_form_at_swept_params():
    pw = SWEPT
    assert float(PWR.v_of_f(pw.f_min, pw)) == pytest.approx(pw.v_min)
    assert float(PWR.v_of_f(pw.f_max, pw)) == pytest.approx(pw.v_max)
    fm = 0.5 * (pw.f_min + pw.f_max)
    assert float(PWR.v_of_f(fm, pw)) == pytest.approx(
        0.5 * (pw.v_min + pw.v_max))
    # default args preserved: the paper's operating point
    assert float(PWR.v_of_f(1.3)) == pytest.approx(0.70)
    assert float(PWR.v_of_f(2.2)) == pytest.approx(1.00)


def test_ivr_eta_and_power_closed_form_at_swept_params():
    pw = SWEPT
    assert float(PWR.ivr_eta(pw.v_min, pw)) == pytest.approx(pw.eta0)
    assert float(PWR.ivr_eta(pw.v_max, pw)) == pytest.approx(
        pw.eta0 + pw.eta_slope)
    f, act = 1.5, 0.7
    v = pw.v_min + ((f - pw.f_min) / (pw.f_max - pw.f_min)) \
        * (pw.v_max - pw.v_min)
    eta = pw.eta0 + pw.eta_slope * (v - pw.v_min) / (pw.v_max - pw.v_min)
    want = (pw.c_eff * v * v * f * act + pw.k_leak * v) / eta
    assert float(PWR.power(f, act, pw)) == pytest.approx(want, rel=1e-6)
    # activity floor (idle leakage-ish clamp) still applies at swept params
    assert float(PWR.power(f, 0.0, pw)) == pytest.approx(
        float(PWR.power(f, 0.05, pw)))


def test_transition_energy_closed_form_at_swept_params():
    pw = SWEPT
    dv = float(PWR.v_of_f(2.0, pw) - PWR.v_of_f(1.0, pw))
    assert float(PWR.transition_energy(1.0, 2.0, pw)) == pytest.approx(
        pw.c_trans * dv * dv, rel=1e-6)
    assert float(PWR.transition_energy(1.5, 1.5, pw)) == 0.0


def test_transition_latency_model():
    # default regime reproduces the paper §5 schedule (back-compat wrapper)
    assert float(PWR.transition_latency_us(1.0)) == pytest.approx(4e-3)
    assert float(PWR.transition_latency_us(10.0)) == pytest.approx(4e-2)
    assert float(PWR.transition_latency_us(100.0)) == pytest.approx(0.4)
    # swept model: 10x slope, higher cap — a slow (legacy) IVR
    pw = SWEPT
    assert float(PWR.transition_latency_us(1.0, pw)) == pytest.approx(4e-2)
    assert float(PWR.transition_latency_us(10.0, pw)) == pytest.approx(0.4)
    assert float(PWR.transition_latency_us(100.0, pw)) == pytest.approx(0.8)
    # traced PowerAxes work the same (the sweep hot path)
    assert float(PWR.transition_latency_us(
        jnp.float32(10.0), pw.axes())) == pytest.approx(0.4)


def test_freqs_ghz_ladder():
    # default regime, jitted (how every executable builds it): bitwise-
    # identical to the module-constant ladder
    jit_ladder = jax.jit(
        lambda pax: PWR.freqs_ghz(pax, 10))(PowerConfig().axes())
    np.testing.assert_array_equal(np.asarray(jit_ladder),
                                  np.asarray(PWR.FREQS_GHZ))
    # swept endpoints + length: exact endpoints, linear spacing
    lad = np.asarray(PWR.freqs_ghz(dataclasses.replace(SWEPT, n_freqs=6)))
    assert lad.shape == (6,)
    assert lad[0] == pytest.approx(SWEPT.f_min)
    assert lad[-1] == SWEPT.f_max  # exact endpoint by construction
    np.testing.assert_allclose(np.diff(lad), 0.2, rtol=1e-5)


# ---------------------------------------------------------------------------
# Power-regime grids through run_grid
# ---------------------------------------------------------------------------

SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=48)


def test_power_grid_bitwise_equal_to_per_point_loop(progs):
    """A power-regime grid reproduces the per-point run_suite loop bitwise
    for every mechanism family (static / traced fork / oracle).

    Bitwise on one device; on a forced multi-device mesh the two
    dispatches shard their (different-length) flat axes to different
    per-device batch shapes, XLA compiles per shape, and the traced power
    operands can land at a different last ulp — so the comparison
    degrades to 1e-5 there (same platform-conditional contract as the
    captured-reference tests)."""
    mechs = ("static17", "crisp", "pcstall", "oracle")
    exact = jax.local_device_count() == 1
    pws = [PowerConfig(), PowerConfig(lat_per_us=4e-2),
           PowerConfig(k_leak=0.6, eta0=0.88)]
    grid = run_grid(progs, SIM, {"power": pws}, mechs)
    for pw in pws:
        suite = run_suite(progs, dataclasses.replace(SIM, power=pw), mechs)
        for wl in WORKLOADS:
            for m in mechs:
                for k, v in suite[wl][m].items():
                    if exact:
                        np.testing.assert_array_equal(
                            grid[(pw,)][wl][m][k], v,
                            err_msg=f"{pw.lat_per_us}/{wl}/{m}/{k}")
                    else:
                        np.testing.assert_allclose(
                            grid[(pw,)][wl][m][k], v, rtol=1e-5, atol=1e-5,
                            err_msg=f"{pw.lat_per_us}/{wl}/{m}/{k}")
    # the regime axis is live: a slower IVR really changes the traces
    a = grid[(pws[0],)]["comd"]["pcstall"]
    b = grid[(pws[1],)]["comd"]["pcstall"]
    assert not np.array_equal(a["work"], b["work"])


def test_power_axis_splits_dedup_rows(progs):
    """Statics are LIVE in the power axes (ladder + energy accounting) —
    unlike objective/table_ema: on a (power x objective) grid static17
    still collapses the objective but splits per power regime, while on a
    (power x table_ema) grid reactive mechanisms split per regime but
    keep collapsing the EMA."""
    sim = dataclasses.replace(SIM, n_cu=12, n_wf=8, n_epochs=24)
    pws = [PowerConfig(), PowerConfig(lat_per_us=4e-1)]
    W = len(WORKLOADS)
    SW.reset_counters()
    run_grid(progs, sim, {"power": pws, "objective": ["ed2p", "edp"]},
             ("static17", "crisp", "pcstall"))
    # static: 2 power classes (objective dead); fork mechs: all 4 points
    assert SW.DISPATCH_ROWS["grid_static17"] == W * 2
    assert SW.DISPATCH_ROWS["grid_forks"] == W * 4 * 2
    SW.reset_counters()
    res = run_grid(progs, sim, {"power": pws, "table_ema": [0.3, 0.5]},
                   ("crisp", "pcstall"))
    # crisp: table_ema dead -> 2 power classes; pcstall: all 4 points
    assert SW.DISPATCH_ROWS["grid_forks"] == W * 2 * 1 + W * 4 * 1
    # the broadcast crisp class trace is bitwise across the dead EMA axis
    for pw in pws:
        a = res[(pw, 0.3)]["comd"]["crisp"]
        b = res[(pw, 0.5)]["comd"]["crisp"]
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # ... but genuinely differs across power regimes
    assert not np.array_equal(res[(pws[0], 0.3)]["comd"]["crisp"]["energy"],
                              res[(pws[1], 0.3)]["comd"]["crisp"]["energy"])


def test_power_grid_rejects_mixed_ladder_lengths(progs):
    with pytest.raises(AssertionError, match="ladder length"):
        run_grid(progs, SIM,
                 {"power": [PowerConfig(), PowerConfig(n_freqs=6)]},
                 ("pcstall",))
    with pytest.raises(AssertionError, match="PowerConfig"):
        run_grid(progs, SIM, {"power": [0.4]}, ("pcstall",))


def test_default_point_bitwise_vs_captured_reference(progs):
    """The default PowerAxes point reproduces the captured reference
    traces bitwise (on the capturing platform; 1e-5 otherwise — jax
    version/backend/device count recorded in the file)."""
    path = Path(__file__).parent / "data" / "grid_reference.npz"
    ref = np.load(path)
    meta = json.loads(bytes(ref["__meta__"]))
    exact = (meta["jax"] == jax.__version__
             and meta["backend"] == jax.default_backend()
             and meta["n_dev"] == jax.local_device_count())
    # the capture's "suite" case: default SimConfig axes = default power
    mechs = ("static17", "pcstall")
    suite = run_suite(progs, SIM, mechs)
    n = 0
    for wl in WORKLOADS:
        for m in mechs:
            for ch, v in suite[wl][m].items():
                k = f"suite|(1.0,)|{wl}|{m}|{ch}"
                if exact:
                    np.testing.assert_array_equal(np.asarray(v), ref[k],
                                                  err_msg=k)
                else:
                    np.testing.assert_allclose(np.asarray(v), ref[k],
                                               rtol=1e-5, atol=1e-5,
                                               err_msg=k)
                n += 1
    assert n > 0


# ---------------------------------------------------------------------------
# Non-default ladders + the IVR-regime acceptance grid
# ---------------------------------------------------------------------------


def test_non_default_ladder_length(progs):
    """A 6-state ladder flows end to end: fidx stays on the ladder, the
    manager's freq_timeshare histogram sizes itself from the power static
    (not the module constant), and off-ladder static indices fail fast."""
    from repro.dvfs_runtime.manager import DVFSManager
    # > 50 epochs: the manager's accuracy metric skips a 50-epoch warmup
    sim = SimConfig(n_cu=8, n_wf=6, n_epochs=64,
                    power=PowerConfig(n_freqs=6))
    tr = run_sim(progs["comd"], sim, "pcstall")
    assert tr["fidx"].max() < 6
    mgr = DVFSManager(program=progs["comd"], sim=sim)
    rep = mgr.report()
    assert len(rep["freq_timeshare"]) == 6
    assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2
    # static22 pins ladder index 9 — off a 6-state ladder, must not wrap
    with pytest.raises(AssertionError, match="off the"):
        run_sim(progs["comd"], sim, "static22")


def test_ivr_regime_grid_two_fork_family_compiles(progs):
    """Acceptance: an IVR-regime sensitivity grid (3 latency models x 2
    epoch lengths) runs through run_grid in <= 2 fork-family compiles,
    and slower IVR regimes really degrade fine-grain DVFS (the paper's
    premise: ns-scale transitions are what unlock 1us epochs)."""
    sim = SimConfig(n_cu=6, n_wf=6, n_epochs=32)  # SimStatic unique here
    regimes = [PowerConfig(),                      # 4ns @ 1us epochs
               PowerConfig(lat_per_us=4e-2),       # 40ns @ 1us
               PowerConfig(lat_per_us=4e-1)]       # 400ns @ 1us
    grid_axes = {"power": regimes, "epoch_us": [1.0, 10.0]}
    SW.reset_counters()
    res = run_grid(progs, sim, grid_axes, ("crisp", "pcstall", "oracle"))
    fork_compiles = sum(v for k, v in SW.TRACE_COUNTS.items()
                        if k in ("grid_forks", "grid_oracle"))
    assert 1 <= fork_compiles <= 2, dict(SW.TRACE_COUNTS)
    assert len(res) == 6
    # repeated sweeps hit the cache
    before = dict(SW.TRACE_COUNTS)
    run_grid(progs, sim, grid_axes, ("crisp", "pcstall", "oracle"))
    assert dict(SW.TRACE_COUNTS) == before
    # physics sanity at 1us epochs: transition dead time scales with the
    # latency regime, so per-epoch useful work under a switching mechanism
    # can only go down as the IVR slows
    w = [res[(pw, 1.0)]["comd"]["pcstall"]["work"].sum() for pw in regimes]
    assert w[0] > w[2]
