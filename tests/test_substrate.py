"""Substrate tests: data pipeline determinism, checkpoint/restore +
fault-tolerant resume, optimizer, elastic policies, sharding specs."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import make_batch
from repro.models import sharding as shard
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.elastic import StragglerDetector, plan_remesh, rescale_batch
from repro.train.train_step import init_state, make_train_step

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def test_data_deterministic_per_step():
    cfg = get_smoke_config("glm4-9b")
    a = make_batch(cfg, SHAPE, step=7)
    b = make_batch(cfg, SHAPE, step=7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = make_batch(cfg, SHAPE, step=8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_host_shards_differ():
    cfg = get_smoke_config("glm4-9b")
    a = make_batch(cfg, SHAPE, 0, host_id=0, n_hosts=2)
    b = make_batch(cfg, SHAPE, 0, host_id=1, n_hosts=2)
    assert a["tokens"].shape[1] == SHAPE.global_batch // 2
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_checkpoint_roundtrip_and_resume():
    cfg = get_smoke_config("phi3-mini-3.8b")
    tc = TrainConfig(total_steps=10)
    state = init_state(cfg, tc, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, step=3)
        restored, step = ckpt.restore(state, d)
        assert step == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_is_bit_exact_training():
    """Crash/restart mid-run must reproduce the uninterrupted trajectory —
    the fault-tolerance contract."""
    cfg = get_smoke_config("glm4-9b")
    tc = TrainConfig(lr=1e-3, total_steps=8, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tc))

    s = init_state(cfg, tc, jax.random.key(1))
    for i in range(6):
        s, _ = step(s, make_batch(cfg, SHAPE, i))
    uninterrupted = s

    with tempfile.TemporaryDirectory() as d:
        s = init_state(cfg, tc, jax.random.key(1))
        for i in range(3):
            s, _ = step(s, make_batch(cfg, SHAPE, i))
        ckpt.save(s, d, step=2)
        # "crash" — restart from the checkpoint
        s2 = init_state(cfg, tc, jax.random.key(1))
        s2, last = ckpt.restore(s2, d)
        for i in range(last + 1, 6):
            s2, _ = step(s2, make_batch(cfg, SHAPE, i))
    for a, b in zip(jax.tree.leaves(uninterrupted["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_ignored():
    cfg = get_smoke_config("glm4-9b")
    state = init_state(cfg, TrainConfig(), jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, step=1)
        # simulate a crash mid-save at step 5: shard written, no manifest
        import pathlib
        p = pathlib.Path(d) / "step_00000005"
        p.mkdir()
        (p / "shard_00000.npz").write_bytes(b"garbage")
        assert ckpt.latest_step(d) == 1


def test_adamw_converges_quadratic():
    tc = TrainConfig(lr=0.05, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, _ = adamw.update(grads, opt, params, tc)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_bounds_update():
    tc = TrainConfig(lr=0.1, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw.init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw.update(grads, opt, params, tc)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_straggler_detector():
    det = StragglerDetector(k=2.0, patience=2)
    for _ in range(10):
        assert det.observe(1.0) == "ok"
    assert det.observe(5.0) == "slow"
    assert det.observe(5.0) == "remesh"
    assert det.observe(1.0) == "ok"  # strikes reset


def test_elastic_remesh_plan():
    assert plan_remesh(2, multi_pod=True) == {"multi_pod": True}
    assert plan_remesh(1, multi_pod=True) == {"multi_pod": False}
    assert rescale_batch(256, 1, 2, keep_global=False) == 128
    assert rescale_batch(256, 1, 2, keep_global=True) == 256


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("axes", [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
])
def test_param_specs_divisible(arch, axes):
    """Every sharded dim must divide its mesh axis — for all 10 archs on
    both production meshes (the dry-run precondition)."""
    cfg = get_config(arch)
    from repro.launch.input_specs import abstract_params
    ap = abstract_params(cfg)
    specs = shard.param_specs(cfg, ap, axes)

    def check(path, leaf, spec):
        for dim, name in zip(leaf.shape, spec):
            if name is None:
                continue
            size = axes[name] if isinstance(name, str) else int(
                np.prod([axes[n] for n in name]))
            assert dim % size == 0, (arch, path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, ap, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))


def test_grad_compression_modes_run():
    cfg = get_smoke_config("glm4-9b")
    for mode in ("bf16", "int8_ef"):
        tc = TrainConfig(lr=1e-3, total_steps=4, grad_compression=mode)
        state = init_state(cfg, tc, jax.random.key(2))
        step = jax.jit(make_train_step(cfg, tc))
        state, m = step(state, make_batch(cfg, SHAPE, 0))
        assert jnp.isfinite(m["loss"]), mode


def test_int8_ef_compression_still_converges():
    """Error-feedback int8 gradient compression must not break optimization."""
    cfg = get_smoke_config("glm4-9b")
    tc = TrainConfig(lr=3e-3, total_steps=15, warmup_steps=2,
                     grad_compression="int8_ef")
    state = init_state(cfg, tc, jax.random.key(7))
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    losses = []
    for i in range(15):
        state, m = step(state, make_batch(cfg, SHAPE, i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_act_sharding_noop_without_mesh():
    import jax.numpy as jnp
    from repro.models import act_sharding as AS
    AS.clear_activation_axes()
    x = jnp.ones((4, 8))
    assert AS.shard_batch(x) is x
    assert AS.shard_heads(x, head_dim=1) is x


def test_cache_specs_divisible():
    from repro.launch.input_specs import decode_inputs
    from repro.configs.base import DECODE_32K
    axes = {"pod": 2, "data": 16, "model": 16}
    for arch in ("llama3-405b", "rwkv6-3b", "hymba-1.5b"):
        cfg = get_config(arch)
        cache, _ = decode_inputs(cfg, DECODE_32K)
        specs = shard.cache_specs(cfg, cache, axes)

        def check(path, leaf, spec):
            for dim, name in zip(leaf.shape, spec):
                if name is None:
                    continue
                size = (axes[name] if isinstance(name, str)
                        else int(np.prod([axes[n] for n in name])))
                assert dim % size == 0, (arch, path, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(check, cache, specs)
