"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import pytest

from repro.core.simulate import SimConfig, prediction_accuracy, run_sim, run_workload
from repro.core.workloads import get_workload

SIM = SimConfig(n_epochs=300)


@pytest.fixture(scope="module")
def comd():
    return get_workload("comd")


def test_mechanism_accuracy_ordering(comd):
    """Paper Fig 14: PC-based prediction beats reactive; oracle is ~exact."""
    acc = {m: prediction_accuracy(run_sim(comd, SIM, m))
           for m in ("crisp", "accreac", "pcstall", "accpc", "oracle")}
    assert acc["oracle"] > 0.97
    assert acc["pcstall"] > acc["crisp"] + 0.05, acc
    assert acc["pcstall"] > acc["accreac"] + 0.05, acc
    assert acc["accpc"] >= acc["pcstall"] - 0.02, acc


def test_dvfs_beats_static17_on_phased_workload(comd):
    r = run_workload(comd, SIM, mechanisms=("static17", "pcstall"))
    assert r["pcstall"]["ednp_norm"] < 0.97  # >3% ED2P gain vs static 1.7


def test_static_frequencies_bracket_dynamic(comd):
    r = run_workload(comd, SIM,
                     mechanisms=("static13", "static22", "pcstall"))
    # dynamic should be at least as good as the WORSE static point
    worst = max(r["static13"]["ednp_norm"], r["static22"]["ednp_norm"])
    assert r["pcstall"]["ednp_norm"] < worst


def test_memory_bound_workload_downclocks():
    tr = run_sim(get_workload("xsbench"), SIM, "pcstall")
    h = np.bincount(tr["fidx"].ravel(), minlength=10) / tr["fidx"].size
    assert h[0] > 0.5, h  # mostly lowest V/f state


def test_compute_bound_workload_upclocks():
    tr = run_sim(get_workload("dgemm"), SIM, "pcstall")
    h = np.bincount(tr["fidx"].ravel(), minlength=10) / tr["fidx"].size
    assert h[-1] > 0.5, h


def test_work_conservation_and_energy_positive(comd):
    tr = run_sim(comd, SIM, "pcstall")
    assert np.all(tr["work"] >= 0)
    assert np.all(tr["energy"] > 0)


def test_granularity_scaling(comd):
    """Paper Fig 18b: larger V/f domains keep most of the benefit."""
    fine = run_workload(comd, SimConfig(n_epochs=300, cus_per_domain=1),
                        mechanisms=("static17", "pcstall"))
    coarse = run_workload(comd, SimConfig(n_epochs=300, cus_per_domain=16,
                                          cus_per_table=16),
                          mechanisms=("static17", "pcstall"))
    assert coarse["pcstall"]["ednp_norm"] < 1.0
    # finer domains should not be (much) worse
    assert fine["pcstall"]["ednp_norm"] <= coarse["pcstall"]["ednp_norm"] + 0.05


def test_perfcap_objective_respects_cap(comd):
    sim = SimConfig(n_epochs=300, objective="perfcap05")
    base = run_sim(comd, SimConfig(n_epochs=300), "static22")
    tr = run_sim(comd, sim, "pcstall")
    # within ~8% of max-frequency work (5% cap + estimation slack)
    assert tr["work"].sum() > 0.92 * base["work"].sum()
    assert tr["energy"].sum() < base["energy"].sum()
