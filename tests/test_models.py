"""Per-architecture smoke tests: reduced same-family config, one train step
+ one decode step on CPU, asserting shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import make_batch
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.train.train_step import init_state, make_train_step

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    tc = TrainConfig(total_steps=10, warmup_steps=2)
    state = init_state(cfg, tc, jax.random.key(0))
    batch = make_batch(cfg, SHAPE, 0)
    step = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["loss"]) > 0
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.sum(jnp.abs(p.astype(jnp.float32)
                                                        - q.astype(jnp.float32)))),
                     state["params"], new_state["params"]))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(1))
    B = 2
    cache = init_cache(cfg, B, max_len=64, fill=0)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tok = jnp.zeros((B,), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b", "paligemma-3b"])
def test_prefill_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(2))
    B, S = 2, 32
    St = S - cfg.n_patches if cfg.frontend == "vision" else S
    batch = {"tokens": jnp.zeros((B, St), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                          jnp.bfloat16)
    logits = jax.jit(lambda p, b: prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_matches_prefill_next_token():
    """Decode with a cache warmed token-by-token must agree with full-seq
    prefill logits (same model, same tokens)."""
    cfg = get_smoke_config("glm4-9b")
    params = init_params(cfg, jax.random.key(3))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab)
    full = prefill(params, cfg, {"tokens": toks})  # logits after last token
    cache = init_cache(cfg, B, max_len=16, fill=0)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    for i in range(S):
        logits, cache = step(params, cache, toks[:, i])
    np.testing.assert_allclose(np.asarray(full), np.asarray(logits),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_loss_decreases_quick_train():
    # 25 steps @ 5e-3 left the MoE arch on its warmup plateau (~0.17 drop);
    # 40 steps @ 1e-2 clears it with ~0.55 of headroom over the 0.3 bar.
    cfg = get_smoke_config("granite-moe-1b-a400m")
    n_steps = 40
    tc = TrainConfig(lr=1e-2, total_steps=n_steps, warmup_steps=3)
    state = init_state(cfg, tc, jax.random.key(5))
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    losses = []
    for i in range(n_steps):
        batch = make_batch(cfg, SHAPE, i)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_pair_scan_attention_matches_ref():
    """The block-causal pair-scan path (Perf #D) is exact vs full softmax."""
    import numpy as np
    from repro.models.layers import attention
    from repro.kernels.ref import attention_ref
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 512, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 512, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 512, 2, 32)), jnp.float32)
    out = attention(q, k, v, causal=True, q_block=128)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
