"""Unit tests for the trip-count-aware HLO analyzer."""
from repro.roofline.hlo_analysis import analyze, parse_module, _multipliers

HLO = """\
HloModule jit_f, entry_computation_layout={(f32[8,8])->f32[8,8]}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={}, to_apply=%add.1
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond.1 (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.1 (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%tpl), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_trip_count_multiplies_flops_and_collectives():
    a = analyze(HLO)
    # dot: 2*8*8*8 = 1024 flops x 10 trips
    assert a["flops"] == 1024 * 10
    # all-reduce result: 8*8*4 bytes x 10
    assert a["coll_all-reduce"] == 256 * 10


def test_multiplier_propagation():
    comps = parse_module(HLO)
    assert set(comps) >= {"body.1", "cond.1", "main.1"}
    mult = _multipliers(comps)
    assert mult["main.1"] == 1.0
    assert mult["body.1"] == 10.0
