"""Equivalence tests for the batched sweep layer and the fused Pallas
PC-table kernels: the batched/compiled fast paths must reproduce the serial
reference paths bitwise (or to f32-roundoff tolerance). ``run_suite`` is a
1-point ``run_grid`` — there is no parallel suite dispatch family — so the
suite/grid equivalence here is bitwise by construction and asserted as
such."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predictors as PRED
from repro.core.simulate import SimConfig, _predict_instr, run_sim
from repro.core.sweep import pad_program, run_grid, run_suite, suite_metrics
from repro.core.workloads import get_workload, make_program

RNG = np.random.default_rng(7)
SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=60)
WORKLOADS = ("comd", "xsbench")
# covers all three families: static (fork-free), reactive CU, PC-table
MECHS = ("static17", "crisp", "pcstall")


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


@pytest.fixture(scope="module")
def suite(progs):
    return run_suite(progs, SIM, MECHS)


@pytest.mark.parametrize("mech", MECHS)
@pytest.mark.parametrize("wl", WORKLOADS)
def test_suite_matches_serial(progs, suite, wl, mech):
    """Batched run_suite == serial run_sim, within 1e-5 (empirically
    bitwise: batching preserves per-row reduction order)."""
    ser = run_sim(progs[wl], SIM, mech)
    bat = suite[wl][mech]
    assert set(ser) == set(bat)
    for k in ser:
        np.testing.assert_allclose(bat[k], ser[k], rtol=1e-5, atol=1e-5,
                                   err_msg=f"{wl}/{mech}/{k}")


def test_suite_matches_serial_oracle_and_accpc(progs):
    """oracle (forks-first path) and accpc (fork-derived table) too."""
    suite = run_suite(progs, SIM, ("accpc", "oracle"))
    for wl in WORKLOADS:
        for mech in ("accpc", "oracle"):
            ser = run_sim(progs[wl], SIM, mech)
            for k in ser:
                np.testing.assert_allclose(suite[wl][mech][k], ser[k],
                                           rtol=1e-5, atol=1e-5,
                                           err_msg=f"{wl}/{mech}/{k}")


def test_padded_program_equivalence():
    """Mixed block counts: padding must not change the wrapped window
    semantics of the shorter program."""
    small = make_program("small", "phased", 5, P=256)
    big = get_workload("comd")  # P=1024
    suite = run_suite([small, big], SIM, ("pcstall",))
    for prog in (small, big):
        ser = run_sim(prog, SIM, "pcstall")
        for k in ser:
            np.testing.assert_allclose(suite[prog.name]["pcstall"][k],
                                       ser[k], rtol=1e-5, atol=1e-5,
                                       err_msg=f"{prog.name}/{k}")


def test_pad_program_preserves_window_averages():
    prog = make_program("p", "mixed", 3, P=128)
    padded = pad_program(prog, 512)
    # wrapped prefix sums agree up to index 2P (the max window extent)
    np.testing.assert_allclose(np.asarray(padded.cum_i0[:257]),
                               np.asarray(prog.cum_i0), rtol=1e-6)
    assert padded.n_blocks == 512


def test_seed_axis(progs):
    out = run_suite(progs, SIM, ("pcstall",), seeds=[0, 3])
    tr = out["comd"]["pcstall"]
    assert tr["work"].shape[0] == 2
    ser = run_sim(progs["comd"], dataclasses.replace(SIM, seed=3), "pcstall")
    np.testing.assert_allclose(tr["work"][1], ser["work"],
                               rtol=1e-5, atol=1e-5)
    # different seeds produce different noise realizations
    assert not np.allclose(tr["work"][0], tr["work"][1])


@pytest.mark.parametrize("epoch_us", [1.0, 10.0, 50.0])
def test_suite_is_one_point_grid_bitwise(progs, epoch_us):
    """run_suite IS a 1-point run_grid: every mechanism family (static,
    traced-id fork, oracle) is bitwise-equal between the two entry points —
    the cross-family last-ulp footgun is unrepresentable."""
    mechs = MECHS + ("oracle",)
    sim = dataclasses.replace(SIM, epoch_us=epoch_us)
    suite = run_suite(progs, sim, mechs)
    grid = run_grid(progs, SIM, {"epoch_us": [epoch_us]}, mechs)[(epoch_us,)]
    for wl in WORKLOADS:
        for m in mechs:
            assert set(suite[wl][m]) == set(grid[wl][m])
            for k, v in suite[wl][m].items():
                np.testing.assert_array_equal(
                    v, grid[wl][m][k], err_msg=f"{epoch_us}/{wl}/{m}/{k}")


def test_large_seeds_with_colliding_f32_images(progs):
    """Regression: seeds ride int32 end-to-end. Two integer seeds above
    2^24 whose float32 images collide (the old path cast seeds to f32 and
    silently aliased them onto one noise stream) must produce distinct
    traces."""
    s1, s2 = 3 * 2 ** 24, 3 * 2 ** 24 + 1
    assert np.float32(s1) == np.float32(s2)  # they DO collide in f32
    out = run_suite(progs, SIM, ("pcstall",), seeds=[s1, s2])
    tr = out["comd"]["pcstall"]
    assert not np.allclose(tr["work"][0], tr["work"][1])
    # and the int32 path matches the serial engine at a large seed too
    ser = run_sim(progs["comd"], dataclasses.replace(SIM, seed=s2), "pcstall")
    np.testing.assert_allclose(tr["work"][1], ser["work"],
                               rtol=1e-5, atol=1e-5)
    # seeds beyond int32 — including >= 2^63 hash-derived ones — fold
    # deterministically to their low 32 bits (no OverflowError) and still
    # get distinct streams
    out64 = run_suite(progs, SIM, ("pcstall",), seeds=[2 ** 63, 2 ** 63 + 1])
    tr64 = out64["comd"]["pcstall"]
    assert not np.allclose(tr64["work"][0], tr64["work"][1])


def test_suite_metrics_matches_run_workload(progs):
    from repro.core.simulate import run_workload
    got = suite_metrics(progs, SIM, MECHS, n=2)
    for wl in WORKLOADS:
        want = run_workload(progs[wl], SIM, mechanisms=MECHS, n=2)
        for m in MECHS:
            for key in ("E", "D", "ednp_norm", "energy_norm"):
                np.testing.assert_allclose(got[wl][m][key], want[m][key],
                                           rtol=1e-5,
                                           err_msg=f"{wl}/{m}/{key}")


# ---------------------------------------------------------------------------
# Pallas kernel equivalence
# ---------------------------------------------------------------------------


def _rand_table(T, E, CU, WF):
    ti0 = jnp.asarray(RNG.uniform(0, 60, (T, E)), jnp.float32)
    tse = jnp.asarray(RNG.uniform(0, 40, (T, E)), jnp.float32)
    tcnt = jnp.asarray((RNG.uniform(size=(T, E)) > 0.4).astype(np.float32))
    tid = jnp.asarray(np.arange(CU) // max(CU // T, 1), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, E, (CU, WF)), jnp.int32)
    fb0 = jnp.asarray(RNG.uniform(0, 60, (CU, WF)), jnp.float32)
    fbs = jnp.asarray(RNG.uniform(0, 40, (CU, WF)), jnp.float32)
    return ti0, tse, tcnt, tid, idx, fb0, fbs


@pytest.mark.parametrize("T,E,CU,WF", [(4, 64, 8, 16), (8, 128, 16, 40)])
def test_pc_table_predict_matches_lookup_plus_predict_instr(T, E, CU, WF):
    """Fused kernel == predictors.table_lookup + simulate._predict_instr."""
    from repro.kernels import ops
    ti0, tse, tcnt, tid, idx, fb0, fbs = _rand_table(T, E, CU, WF)
    sim = SimConfig(n_cu=CU, n_wf=WF)
    from repro.core import power as PWR
    out = ops.pc_table_predict(ti0, tse, tcnt, tid, idx, fb0, fbs,
                               PWR.FREQS_GHZ, epoch_us=sim.epoch_us,
                               cap_per_ghz=sim.cap_per_ghz)
    i0w, sw, _ = PRED.table_lookup(PRED.PCTable(ti0, tse, tcnt), tid, idx,
                                   fb0, fbs)
    want = _predict_instr(i0w.sum(-1), sw.sum(-1), sim.static_part(),
                          sim.axes())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("T,E,CU,WF", [(4, 64, 8, 16), (8, 128, 16, 40)])
def test_pc_table_update_matches_predictors(T, E, CU, WF):
    """Fused update kernel == predictors.table_update (contiguous tid)."""
    from repro.kernels import ops, ref
    ti0, tse, tcnt, tid, idx, fb0, fbs = _rand_table(T, E, CU, WF)
    N = (CU // T) * WF
    ui, us_, uc = ops.pc_table_update(ti0, tse, tcnt, idx.reshape(T, N),
                                      fb0.reshape(T, N), fbs.reshape(T, N),
                                      ema=0.5)
    want = PRED.table_update(PRED.PCTable(ti0, tse, tcnt), tid, idx,
                             fb0, fbs, 0.5)
    np.testing.assert_allclose(np.asarray(ui), np.asarray(want.i0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(us_), np.asarray(want.sens),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(uc), np.asarray(want.count),
                               rtol=1e-6, atol=0)
    # and the jnp oracle agrees exactly
    ri, rs, rc = ref.pc_table_update_ref(ti0, tse, tcnt, idx.reshape(T, N),
                                         fb0.reshape(T, N),
                                         fbs.reshape(T, N), ema=0.5)
    np.testing.assert_array_equal(np.asarray(ui), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(us_), np.asarray(rs))


def test_run_sim_use_pallas_v1_matches_jnp():
    """The pcstall/accpc predict+update hot path through the v1 fused
    PC-table kernel pair reproduces the jnp path per-epoch."""
    prog = get_workload("comd")
    for mech in ("pcstall", "accpc"):
        a = run_sim(prog, SIM, mech)
        b = run_sim(prog, dataclasses.replace(SIM, use_pallas="v1"), mech)
        for k in a:
            np.testing.assert_allclose(b[k], a[k], rtol=1e-4, atol=1e-4,
                                       err_msg=f"{mech}/{k}")


def test_run_sim_use_pallas_v2_matches_jnp_aggregates():
    """The v2 single fused epoch kernel (use_pallas=True auto-selects it
    for every traced fork mechanism) reproduces the jnp path at the
    aggregate level. Per-epoch traces are NOT compared: the lean math
    reassociates float reductions, argmin near-ties flip and the closed
    loop is chaotic from there (see kernels.epoch_fused docstring) — the
    contract is aggregate work/energy within ~1e-3 relative."""
    prog = get_workload("comd")
    for mech, cfg in (("pcstall", True), ("accpc", "v2"), ("stall", "v2"),
                      ("crisp", "v2"), ("accreac", "v2")):
        a = run_sim(prog, SIM, mech)
        b = run_sim(prog, dataclasses.replace(SIM, use_pallas=cfg), mech)
        assert set(a) == set(b)
        for k in ("work", "energy"):
            ra = float(np.sum(a[k]))
            rb = float(np.sum(b[k]))
            assert abs(ra - rb) / abs(ra) < 2e-3, (mech, k, ra, rb)
        # discrete outputs stay in range and mostly agree
        agree = float(np.mean(np.asarray(a["fidx"]) == np.asarray(b["fidx"])))
        assert agree > 0.5, (mech, agree)


def test_run_sim_use_pallas_v2_exact_fallbacks():
    """Mechanisms v2 cannot serve (oracle: forks-first, static: no fork)
    fall back without error under use_pallas=True, matching jnp."""
    prog = get_workload("comd")
    for mech in ("oracle", "static17"):
        a = run_sim(prog, SIM, mech)
        b = run_sim(prog, dataclasses.replace(SIM, use_pallas=True), mech)
        for k in a:
            np.testing.assert_allclose(b[k], a[k], rtol=1e-4, atol=1e-4,
                                       err_msg=f"{mech}/{k}")


def test_run_grid_use_pallas_v2_matches_jnp_aggregates(progs):
    """Tentpole acceptance: the fused epoch kernel as a grid ENGINE mode.
    ``use_pallas='v2'`` swaps the scan body inside the shared traced-id
    fork executable, so a multi-point grid over every traced family still
    compiles exactly ONE fork-family executable and dispatches exactly
    (workloads x points x mechs) dedup-accounted rows — while the results
    track the jnp engine at aggregate tolerance (per-epoch traces diverge
    chaotically from lean-math argmin near-tie flips; the selected row
    itself is exact, see the kernel docstring)."""
    from repro.core import sweep as SW
    sim = SimConfig(n_cu=8, n_wf=14, n_epochs=48)
    grid = {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]}
    mechs = ("stall", "crisp", "accreac", "pcstall", "accpc")
    ref = run_grid(progs, sim, grid, mechs)
    SW.reset_counters()
    v2 = run_grid(progs, dataclasses.replace(sim, use_pallas="v2"),
                  grid, mechs)
    assert SW.TRACE_COUNTS["grid_forks"] == 1
    assert SW.DISPATCH_ROWS["grid_forks"] == len(WORKLOADS) * 4 * len(mechs)
    for key in ref:
        for wl in WORKLOADS:
            for m in mechs:
                a, b = ref[key][wl][m], v2[key][wl][m]
                assert set(a) == set(b), (key, wl, m)
                for k in ("work", "energy"):
                    ra, rb = float(np.sum(a[k])), float(np.sum(b[k]))
                    assert abs(ra - rb) / abs(ra) < 2e-3, \
                        (key, wl, m, k, ra, rb)


def test_run_grid_use_pallas_v2_fallback_specs_bitwise(progs):
    """Specs the v2 kernel cannot serve (static: no forks; oracle:
    forks-first selection) fall back to the unfused body under
    ``use_pallas=True`` — BITWISE, since their executables trace the
    identical jnp scan."""
    sim = SimConfig(n_cu=8, n_wf=10, n_epochs=40)
    grid = {"epoch_us": [1.0, 10.0]}
    a = run_grid(progs, sim, grid, ("static17", "oracle"))
    b = run_grid(progs, dataclasses.replace(sim, use_pallas=True),
                 grid, ("static17", "oracle"))
    for key in a:
        for wl in WORKLOADS:
            for m in ("static17", "oracle"):
                for k, v in a[key][wl][m].items():
                    np.testing.assert_array_equal(
                        v, b[key][wl][m][k], err_msg=f"{key}/{wl}/{m}/{k}")


def test_run_grid_v2_block_cu_inert_on_interpret(progs):
    """``pallas_block_cu`` only selects the blocked kernel pair through a
    real (or via_pallas-forced) pallas_call; on the direct-eval interpret
    engine the monolithic body runs either way — bitwise."""
    sim = SimConfig(n_cu=8, n_wf=10, n_epochs=40, use_pallas="v2")
    grid = {"epoch_us": [1.0, 10.0]}
    mechs = ("crisp", "pcstall")
    a = run_grid(progs, sim, grid, mechs)
    b = run_grid(progs, dataclasses.replace(sim, pallas_block_cu=4),
                 grid, mechs)
    for key in a:
        for wl in WORKLOADS:
            for m in mechs:
                for k, v in a[key][wl][m].items():
                    np.testing.assert_array_equal(
                        v, b[key][wl][m][k], err_msg=f"{key}/{wl}/{m}/{k}")
