"""Streaming-service tests: micro-batched streamed dispatch must be a
bitwise re-dispatch of the existing grid graph (``grid_reference.npz`` is
the frozen contract — NO re-capture), padding to a static bucket must be
invisible in results, oob requests must fail fast, and the async service
must serve a stream with <= 2 fork-family compiles end to end."""
import dataclasses
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import sweep as SW
from repro.core.simulate import SimConfig
from repro.core.sweep import GridExecutor, run_grid
from repro.core.workloads import get_workload, make_program
from repro.data.pipeline import dvfs_request_stream
from repro.dvfs_runtime.service import DVFSService

SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=48)
WORKLOADS = ("comd", "xsbench")
MECHS = ("static17", "crisp", "pcstall", "oracle")
# the reference's grid2x2 case re-expressed as a request stream: one job
# per (workload, epoch_us, objective), in capture order
GRID2X2_JOBS = [(wl, {"epoch_us": e, "objective": o})
                for e in (1.0, 10.0) for o in ("ed2p", "edp")
                for wl in WORKLOADS]


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


def _reference():
    path = Path(__file__).parent / "data" / "grid_reference.npz"
    ref = np.load(path)
    meta = json.loads(bytes(ref["__meta__"]))
    exact = (meta["jax"] == jax.__version__
             and meta["backend"] == jax.default_backend()
             and meta["n_dev"] == jax.local_device_count())
    return ref, exact


def _assert_vs_ref(got, ref, exact, key):
    if exact:
        np.testing.assert_array_equal(np.asarray(got), ref[key], err_msg=key)
    else:
        np.testing.assert_allclose(np.asarray(got), ref[key],
                                   rtol=1e-5, atol=1e-5, err_msg=key)


def test_streamed_micro_batches_bitwise_vs_captured_reference(progs):
    """Acceptance: the grid2x2 reference case, re-expressed as a stream of
    single-job requests and dispatched in micro-batches of 3 padded to a
    static bucket of 4, reproduces the captured one-shot ``run_grid``
    traces bitwise (on the capturing platform; 1e-5 otherwise). The
    stream must ride the existing dispatch graph — the reference file is
    NOT re-captured."""
    ref, exact = _reference()
    ex = GridExecutor(SIM, MECHS, buckets=(4,))
    jobs = [(progs[wl], ov) for wl, ov in GRID2X2_JOBS]
    results = []
    for i in range(0, len(jobs), 3):  # 8 jobs -> batches of 3, 3, 2
        results.extend(ex.run(jobs[i:i + 3]))
    n = 0
    for (wl, ov), trs in zip(GRID2X2_JOBS, results):
        key = (ov["epoch_us"], ov["objective"])
        for m in MECHS:
            for ch, v in trs[m].items():
                _assert_vs_ref(v, ref, exact,
                               f"grid2x2|{key!r}|{wl}|{m}|{ch}")
                n += 1
    # full coverage: every captured grid2x2 array for these mechanisms
    # was compared against a streamed row
    want = sum(1 for k in ref.files
               if k.startswith("grid2x2|") and k.split("|")[3] in MECHS)
    assert n == want > 0


def test_executor_padding_smaller_than_bucket(progs):
    """A micro-batch smaller than its static shape: pad rows (cycled jobs)
    are dropped on unpack — same per-job rows as an exact-size dispatch,
    and the batch shape (not the job count) keys the jit cache."""
    ex = GridExecutor(SIM, ("pcstall",), buckets=(8,))
    jobs = [(progs["comd"], {"epoch_us": 1.0}),
            (progs["xsbench"], {"epoch_us": 10.0}),
            (progs["comd"], {"epoch_us": 50.0})]
    pending = ex.dispatch(jobs)
    assert pending.n_jobs == 3
    padded = pending.traces()
    assert len(padded) == 3
    exact = GridExecutor(SIM, ("pcstall",)).run(jobs)  # buckets=None
    for a, b, (_, ov) in zip(padded, exact, jobs):
        for ch in a["pcstall"]:
            np.testing.assert_allclose(
                a["pcstall"][ch], b["pcstall"][ch], rtol=1e-5, atol=1e-5,
                err_msg=f"{ov}/{ch}")


def test_executor_oob_requests(progs):
    """Requests the static shapes cannot admit fail fast at dispatch."""
    ex = GridExecutor(SIM, ("pcstall",), p_max=1024, buckets=(2,))
    job = (progs["comd"], {})
    with pytest.raises(AssertionError, match="exceeds the largest"):
        ex.dispatch([job, job, job])  # batch > largest bucket
    with pytest.raises(AssertionError, match="not a traced grid axis"):
        ex.dispatch([(progs["comd"], {"n_cu": 8})])
    with pytest.raises(AssertionError, match="exceeds the executor"):
        ex.dispatch([(progs["comd"], {"n_epochs": SIM.n_epochs + 1})])
    small = GridExecutor(SIM, ("pcstall",), p_max=256, buckets=(2,))
    with pytest.raises(AssertionError, match="blocks"):
        small.dispatch([(progs["comd"], {})])  # 1024-block program
    big = make_program("small_svc", "phased", 5, P=256)
    small.run([(big, {})])  # within p_max: fine


def test_service_stream_two_fork_family_compiles_and_bitwise(progs):
    """Acceptance: a whole async request stream (trickled submits, forced
    coalescing into short micro-batches) is served by <= 2 fork-family
    compiles (TRACE_COUNTS) and every streamed row equals the one-shot
    ``run_grid`` answer for the same jobs. Uses a SimStatic no other test
    shares (n_wf=10) so the compile count is established in-test."""
    sim = dataclasses.replace(SIM, n_wf=10)
    before = dict(SW.TRACE_COUNTS)
    with DVFSService(sim, mechanism="oracle", baseline="pcstall",
                     max_batch=3, coalesce_s=0.005) as svc:
        futs = [svc.submit(progs[wl], ov) for wl, ov in GRID2X2_JOBS]
        results = [f.result(timeout=600) for f in futs]
        stats = svc.stats()
    fork = {k: SW.TRACE_COUNTS[k] - before.get(k, 0)
            for k in ("grid_forks", "grid_oracle")}
    assert 1 <= sum(fork.values()) <= 2, fork
    assert stats["jobs"] == len(GRID2X2_JOBS)
    assert stats["batches"] >= 3  # max_batch bounds coalescing
    ref = run_grid(progs, sim, {"epoch_us": [1.0, 10.0],
                                "objective": ["ed2p", "edp"]},
                   ("pcstall", "oracle"))
    for (wl, ov), res in zip(GRID2X2_JOBS, results):
        want = ref[(ov["epoch_us"], ov["objective"])][wl]
        for m in ("pcstall", "oracle"):
            for ch, v in want[m].items():
                np.testing.assert_array_equal(
                    np.asarray(res["traces"][m][ch]), np.asarray(v),
                    err_msg=f"{wl}/{ov}/{m}/{ch}")
        rep = res["report"]
        assert rep["step_time"]["n_steps"] == 0
        assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2


def test_service_async_api_and_lifecycle(progs):
    """submit never blocks on the device, futures carry latency + report
    with the request's own telemetry stats, stats() percentiles are
    ordered, close() drains FIFO, and a closed service rejects submits."""
    svc = DVFSService(SIM, max_batch=4, coalesce_s=0.001)
    futs = [svc.submit(progs["comd"], {"epoch_us": float(e)},
                       telemetry=[(i, 0.01 * (i + 1)) for i in range(3)])
            for e in (1.0, 2.0, 5.0)]
    assert not all(f.done() for f in futs)  # async: accept loop returned
    svc.close()  # drains: everything submitted above still resolves
    for f in futs:
        res = f.result(timeout=60)
        assert res["latency_s"] > 0 and 1 <= res["batch_size"] <= 4
        st = res["report"]["step_time"]
        assert st["n_steps"] == 3
        assert (st["first_step"], st["last_step"]) == (0, 2)
        np.testing.assert_allclose(st["mean_step_s"], 0.02)
        np.testing.assert_allclose(res["report"]["mean_step_s"], 0.02)
    stats = svc.stats()
    assert stats["jobs"] == 3 and stats["jobs_per_sec"] > 0
    assert 0 < stats["p50_latency_s"] <= stats["p99_latency_s"] \
        <= stats["max_latency_s"]
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(progs["comd"])
    svc.close()  # idempotent


def test_service_propagates_bad_request_errors(progs):
    """A bad request fails its own future (the whole batch it coalesced
    into), and the service keeps serving afterwards."""
    with DVFSService(SIM, max_batch=1, coalesce_s=0.0) as svc:
        bad = svc.submit(progs["comd"], {"n_cu": 4})  # static, not an axis
        with pytest.raises(AssertionError, match="not a traced grid axis"):
            bad.result(timeout=60)
        good = svc.submit(progs["comd"], {"epoch_us": 1.0})
        assert "traces" in good.result(timeout=600)


def test_dvfs_request_stream_deterministic():
    """The pipeline's request stream is counter-based: same seed replays
    bit-identically (programs, axes, telemetry), different seeds differ."""
    a = list(dvfs_request_stream(6, seed=3))
    b = list(dvfs_request_stream(6, seed=3))
    c = list(dvfs_request_stream(6, seed=4))
    for (pa, xa, ta), (pb, xb, tb) in zip(a, b):
        assert pa.name == pb.name and xa == xb and ta == tb
    assert any(xa != xc or ta != tc or pa.name != pc.name
               for (pa, xa, ta), (pc, xc, tc) in zip(a, c))
    for prog, axes, tel in a:
        assert set(axes) <= {"epoch_us", "objective"}
        assert len(tel) == 4 and all(t > 0 for _, t in tel)


def test_executor_batch1_bitwise_vs_oneshot(progs):
    """Satellite acceptance: a batch-1 flat dispatch (buckets=None, one
    job) is padded to the executor's 2-row bucket floor — a 1-row leading
    axis lets XLA fuse it away and codegen f32 chains at a shifted last
    ulp, which silently broke the bitwise streamed-vs-one-shot contract
    for singleton requests. Per-job executor rows must now equal the
    multi-row ``run_grid`` answer EXACTLY."""
    mechs = ("pcstall", "crisp")
    ex = GridExecutor(SIM, mechs)          # buckets=None: flat dispatch
    ref = run_grid(progs, SIM, {"epoch_us": [1.0, 10.0],
                                "objective": ["ed2p", "edp"]}, mechs)
    for wl, ov in GRID2X2_JOBS:
        res = ex.run([(progs[wl], ov)])[0]     # batch of ONE
        want = ref[(ov["epoch_us"], ov["objective"])][wl]
        for m in mechs:
            for ch, v in want[m].items():
                np.testing.assert_array_equal(
                    np.asarray(res[m][ch]), np.asarray(v),
                    err_msg=f"{wl}/{ov}/{m}/{ch}")


def test_executor_streams_v2_engine_bitwise_vs_oneshot_v2(progs):
    """Tentpole thread-through: a GridExecutor built on a ``use_pallas='v2'``
    SimConfig inherits the fused-kernel engine — streamed micro-batches
    equal the one-shot v2 ``run_grid`` bitwise, served by <= 2 new
    fork-family compiles. Uses a SimStatic no other test shares
    (n_wf=14 + v2) so the compile delta is established in-test."""
    sim = dataclasses.replace(SIM, n_wf=14, use_pallas="v2")
    mechs = ("pcstall", "crisp")
    SW.reset_counters()
    ex = GridExecutor(sim, mechs, buckets=(4,))
    jobs = [(progs[wl], ov) for wl, ov in GRID2X2_JOBS]
    results = []
    for i in range(0, len(jobs), 3):
        results.extend(ex.run(jobs[i:i + 3]))
    ref = run_grid(progs, sim, {"epoch_us": [1.0, 10.0],
                                "objective": ["ed2p", "edp"]}, mechs)
    fork = {k: v for k, v in SW.TRACE_COUNTS.items()
            if k in ("grid_forks", "grid_oracle")}
    assert 1 <= sum(fork.values()) <= 2, fork
    for (wl, ov), res in zip(GRID2X2_JOBS, results):
        want = ref[(ov["epoch_us"], ov["objective"])][wl]
        for m in mechs:
            for ch, v in want[m].items():
                np.testing.assert_array_equal(
                    np.asarray(res[m][ch]), np.asarray(v),
                    err_msg=f"{wl}/{ov}/{m}/{ch}")
