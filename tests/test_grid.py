"""Equivalence and caching tests for the device-sharded grid sweep
(``repro.core.sweep.run_grid`` — the ONE dispatch path every sweep uses):
a whole (epoch_us x objective) figure grid must (a) reproduce per-point
``run_suite`` results (bitwise — run_suite is itself a 1-point run_grid) —
including masked logical-epoch tails and padded mixed-size workloads —
(b) compile at most two fork-family executables regardless of grid size,
and (c) execute each static-frequency mechanism once per
``STATIC_EXEC_AXES`` equivalence class, not once per grid point."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import sweep as SW
from repro.core.simulate import SimConfig, objective_weights, run_sim
from repro.core.sweep import run_grid, run_suite
from repro.core.workloads import get_workload, make_program

SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=48)
WORKLOADS = ("comd", "xsbench")
MECHS = ("static17", "crisp", "pcstall", "oracle")
GRID_2X2 = {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]}


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


@pytest.fixture(scope="module")
def grid_2x2(progs):
    return run_grid(progs, SIM, GRID_2X2, MECHS)


def _assert_traces_match(got, want, ctx):
    assert set(got) == set(want), ctx
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5,
                                   err_msg=f"{ctx}/{k}")


@pytest.mark.parametrize("key", [(1.0, "ed2p"), (1.0, "edp"),
                                 (10.0, "ed2p"), (10.0, "edp")])
def test_grid_matches_per_point_suite(progs, grid_2x2, key):
    """2x2 (epoch_us x objective) grid == per-point run_suite, <= 1e-5
    (empirically bitwise: same traced-id executable family)."""
    sim_pt = dataclasses.replace(SIM, epoch_us=key[0], objective=key[1])
    suite = run_suite(progs, sim_pt, MECHS)
    for wl in WORKLOADS:
        for m in MECHS:
            _assert_traces_match(grid_2x2[key][wl][m], suite[wl][m],
                                 f"{key}/{wl}/{m}")


def test_grid_fork_family_executable_count(progs):
    """Acceptance: a >= 2x2 grid compiles <= 2 fork-family executables
    (the traced-id family + oracle's specialized one) and at least one,
    and repeated calls hit the jit cache (no new traces).

    Uses a SimStatic no other test shares (n_cu=8) so the executables are
    compiled *inside this test* — a cached fixture grid would make the
    count vacuous."""
    sim = dataclasses.replace(SIM, n_cu=8)
    SW.reset_counters()
    run_grid(progs, sim, GRID_2X2, MECHS)
    fork_traces = {k: v for k, v in SW.TRACE_COUNTS.items()
                   if k in ("grid_forks", "grid_oracle")}
    assert 1 <= sum(fork_traces.values()) <= 2, fork_traces
    before = dict(SW.TRACE_COUNTS)
    run_grid(progs, sim, GRID_2X2, MECHS)
    assert dict(SW.TRACE_COUNTS) == before  # cache hit: zero new compiles


def test_static_mech_dedup_rows_and_broadcast(progs):
    """Acceptance: on a multi-objective grid each static mechanism compiles
    AND executes once per (epoch_us, sigma, cap_per_ghz, membw) equivalence
    class — a 3-objective grid must not triple static-mech compute — and
    the class trace is broadcast bitwise to every member grid key."""
    sim = dataclasses.replace(SIM, n_cu=4)  # SimStatic unique to this test
    grid = {"epoch_us": [1.0, 10.0],
            "objective": ["ed2p", "edp", "perfcap05"]}
    W, G, C = len(WORKLOADS), 6, 2
    SW.reset_counters()
    res = run_grid(progs, sim, grid, ("static17", "pcstall"))
    assert SW.DISPATCH_ROWS["grid_forks"] == W * G
    assert SW.DISPATCH_ROWS["grid_static17"] == W * C   # deduped rows
    assert SW.TRACE_COUNTS["grid_static17"] == 1        # one compile
    run_grid(progs, sim, grid, ("static17", "pcstall"))
    assert SW.TRACE_COUNTS["grid_static17"] == 1        # jit cache hit
    assert SW.DISPATCH_ROWS["grid_static17"] == 2 * W * C
    for T in (1.0, 10.0):
        for wl in WORKLOADS:
            a = res[(T, "ed2p")][wl]["static17"]
            for obj in ("edp", "perfcap05"):
                b = res[(T, obj)][wl]["static17"]
                for k in a:
                    np.testing.assert_array_equal(
                        a[k], b[k], err_msg=f"{T}/{obj}/{wl}/{k}")
        # the deduped trace still equals a per-point run_suite
        suite = run_suite(progs, dataclasses.replace(sim, epoch_us=T),
                          ("static17",))
        for wl in WORKLOADS:
            _assert_traces_match(res[(T, "ed2p")][wl]["static17"],
                                 suite[wl]["static17"], f"dedup/{T}/{wl}")


def test_static_dedup_coupled_epoch_counts(progs):
    """Points sharing execution axes but differing in logical n_epochs form
    ONE class: the representative scans to the class max and each member
    slices its logical prefix."""
    points = [{"epoch_us": 1.0, "n_epochs": 24, "objective": "ed2p"},
              {"epoch_us": 1.0, "n_epochs": 48, "objective": "edp"}]
    SW.reset_counters()
    res = run_grid(progs, SIM, points, ("static17",))
    assert SW.DISPATCH_ROWS["grid_static17"] == len(WORKLOADS)  # one class
    for pt in points:
        key = (1.0, pt["n_epochs"], pt["objective"])
        suite = run_suite(progs,
                          dataclasses.replace(SIM, n_epochs=pt["n_epochs"]),
                          ("static17",))
        for wl in WORKLOADS:
            got = res[key][wl]["static17"]
            assert got["work"].shape[0] == pt["n_epochs"]
            _assert_traces_match(got, suite[wl]["static17"], f"{key}/{wl}")


def test_grid_point_key_order_normalized(progs):
    """List-of-dicts points delivering the same axes in different key
    insertion order describe the same grid (keys follow the first point's
    axis order); genuinely different axis *sets* still assert."""
    a = run_grid(progs, SIM, [{"epoch_us": 1.0, "n_epochs": 32},
                              {"n_epochs": 48, "epoch_us": 10.0}],
                 ("pcstall",))
    assert list(a) == [(1.0, 32), (10.0, 48)]
    b = run_grid(progs, SIM, [{"epoch_us": 1.0, "n_epochs": 32},
                              {"epoch_us": 10.0, "n_epochs": 48}],
                 ("pcstall",))
    for key in a:
        for wl in WORKLOADS:
            for k, v in a[key][wl]["pcstall"].items():
                np.testing.assert_array_equal(v, b[key][wl]["pcstall"][k])
    with pytest.raises(AssertionError, match="share axes"):
        run_grid(progs, SIM, [{"epoch_us": 1.0}, {"sigma": 0.1}],
                 ("pcstall",))


def test_grid_masked_epoch_tail(progs):
    """Coupled (epoch_us, n_epochs) points: the shorter point scans to the
    grid max with its tail masked, and still matches a run_suite sized
    exactly to its logical epoch count."""
    points = [{"epoch_us": 1.0, "n_epochs": 32},
              {"epoch_us": 10.0, "n_epochs": 48}]
    grid = run_grid(progs, SIM, points, ("static17", "pcstall"))
    for pt in points:
        key = (pt["epoch_us"], pt["n_epochs"])
        sim_pt = dataclasses.replace(SIM, **pt)
        suite = run_suite(progs, sim_pt, ("static17", "pcstall"))
        for wl in WORKLOADS:
            for m in ("static17", "pcstall"):
                got = grid[key][wl][m]
                assert got["work"].shape[0] == pt["n_epochs"]
                _assert_traces_match(got, suite[wl][m], f"{key}/{wl}/{m}")


def test_grid_mask_ratio_bucketing(progs):
    """max_mask_ratio splits strongly-coupled n_epochs points into
    bounded-waste buckets without changing results or key order."""
    points = [{"epoch_us": 1.0, "n_epochs": 48},
              {"epoch_us": 10.0, "n_epochs": 12},
              {"epoch_us": 50.0, "n_epochs": 12}]
    whole = run_grid(progs, SIM, points, ("pcstall",))
    bucketed = run_grid(progs, SIM, points, ("pcstall",), max_mask_ratio=2.0)
    assert list(bucketed) == list(whole)  # caller's point order preserved
    for key in whole:
        for wl in WORKLOADS:
            _assert_traces_match(bucketed[key][wl]["pcstall"],
                                 whole[key][wl]["pcstall"], f"bucket/{key}")


def test_grid_padded_workload_mix():
    """Mixed block counts ride the grid unchanged: padding must not change
    the wrapped window semantics of the shorter program."""
    small = make_program("small", "phased", 5, P=256)
    big = get_workload("comd")  # P=1024
    grid = run_grid([small, big], SIM, {"epoch_us": [1.0, 10.0]},
                    ("pcstall",))
    for T in (1.0, 10.0):
        suite = run_suite([small, big],
                          dataclasses.replace(SIM, epoch_us=T), ("pcstall",))
        for prog in (small, big):
            _assert_traces_match(grid[(T,)][prog.name]["pcstall"],
                                 suite[prog.name]["pcstall"],
                                 f"{T}/{prog.name}")


def test_grid_odd_flat_axis(progs):
    """A flat (workload x grid-point) axis that is not a device multiple
    exercises the _pad_flat cycling path on multi-device hosts (and is the
    identity on one device, where the mesh is capped at the flat length);
    either way every row matches the serial engine."""
    three = {**progs, "small": make_program("small", "phased", 5, P=256)}
    res = run_grid(three, SIM, {"epoch_us": [1.0]}, ("pcstall",))[(1.0,)]
    for name, prog in three.items():
        ser = run_sim(prog, SIM, "pcstall")
        for k in ser:
            np.testing.assert_allclose(res[name]["pcstall"][k], ser[k],
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{name}/{k}")


def test_grid_seed_axis(progs):
    out = run_grid(progs, SIM, {"objective": ["ed2p"]}, ("pcstall",),
                   seeds=[0, 3])
    tr = out[("ed2p",)]["comd"]["pcstall"]
    assert tr["work"].shape[:2] == (2, SIM.n_epochs)
    want = run_suite(progs, SIM, ("pcstall",), seeds=[0, 3])
    np.testing.assert_allclose(tr["work"], want["comd"]["pcstall"]["work"],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(jax.local_device_count() != 1,
                    reason="identity-mesh check is 1-device-specific "
                           "(multi-device equivalence holds too — run this "
                           "file under a forced multi-device config)")
def test_grid_runs_under_one_device_shard_map(progs):
    """The flattened (workload x grid-point) axis is sharded via shard_map;
    on this host that is a 1-device mesh, which must be the identity
    layout — results already checked against run_suite above."""
    res = run_grid(progs, SIM, {"epoch_us": [1.0]}, ("pcstall",))
    ser = run_suite(progs, SIM, ("pcstall",))
    for wl in WORKLOADS:
        _assert_traces_match(res[(1.0,)][wl]["pcstall"],
                             ser[wl]["pcstall"], wl)


def test_grid_rejects_static_axis(progs):
    with pytest.raises(AssertionError, match="not a traced grid axis"):
        run_grid(progs, SIM, {"n_cu": [8, 16]}, ("pcstall",))


def test_objective_weights_lowering():
    np.testing.assert_allclose(objective_weights("edp"), [1.0, 1.0, 0.0])
    np.testing.assert_allclose(objective_weights("ed2p"), [2.0, 1.0, 0.0])
    np.testing.assert_allclose(objective_weights("perfcap05"),
                               [0.0, 0.0, 0.95])
    np.testing.assert_allclose(objective_weights("perfcap10"),
                               [0.0, 0.0, 0.90])
    with pytest.raises(ValueError):
        objective_weights("nope")


def test_axis_change_does_not_retrace(progs):
    """The SimConfig split: sweeping any traced axis through run_suite
    reuses the same executable (no new compile)."""
    run_suite(progs, SIM, ("pcstall",))
    before = dict(SW.TRACE_COUNTS)
    for repl in ({"epoch_us": 3.0}, {"objective": "perfcap10"},
                 {"sigma": 0.01}, {"membw": 2e5}, {"table_ema": 0.3},
                 {"cap_per_ghz": 4000.0}):
        run_suite(progs, dataclasses.replace(SIM, **repl), ("pcstall",))
    assert dict(SW.TRACE_COUNTS) == before
