"""Hypothesis property tests on system invariants.

Skipped wholesale when ``hypothesis`` is not installed (the container image
does not ship it); the invariants are also exercised deterministically by
tests/test_core.py and tests/test_sweep.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core.simulate import SimConfig, epoch_execute
from repro.core.workloads import make_program
from repro.models.layers import chunked_ce_loss

SETTINGS = dict(max_examples=20, deadline=None)


@given(seed=st.integers(0, 2**16), f_idx=st.integers(0, 9))
@settings(**SETTINGS)
def test_epoch_invariants(seed, f_idx):
    """committed in [0, demand-cap]; issue ratio in [0,1]; counters finite."""
    prog = make_program("p", "irregular", seed % 97, P=256)
    sim = SimConfig(n_cu=4, n_wf=8, seed=seed % 13)
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.uniform(0, 256 * 4, (4, 8)), jnp.float32)
    f = jnp.full((4,), float(PWR.FREQS_GHZ[f_idx]))
    committed, ctr = epoch_execute(prog, pos, f, sim)
    assert bool(jnp.all(committed >= 0))
    assert bool(jnp.all(ctr["steady"] >= committed - 1e-3))
    assert bool(jnp.all((ctr["issue_q"] >= 0) & (ctr["issue_q"] <= 1 + 1e-6)))
    assert bool(jnp.all((ctr["core_frac"] >= 0) & (ctr["core_frac"] <= 1)))
    # CU issue capacity respected
    C = sim.cap_per_ghz * f[:, None] * sim.epoch_us
    assert bool(jnp.all(committed.sum(-1) <= C[:, 0] + 1e-3))


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_steady_monotone_in_frequency(seed):
    """Without shared-bandwidth thrash, steady committed is monotone
    non-decreasing in frequency (linear model property)."""
    prog = make_program("p", "mixed", seed % 89, P=256)
    sim = SimConfig(n_cu=2, n_wf=4, membw=1e12, seed=seed % 7)
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.uniform(0, 256 * 4, (2, 4)), jnp.float32)
    outs = [epoch_execute(prog, pos, jnp.full((2,), float(f)), sim)[1]["steady"].sum()
            for f in PWR.FREQS_GHZ]
    assert all(float(b) >= float(a) - 1e-2 for a, b in zip(outs, outs[1:]))


@given(data=st.data())
@settings(**SETTINGS)
def test_pc_table_lookup_returns_written_values(data):
    entries = data.draw(st.sampled_from([8, 32, 128]))
    n_wf = data.draw(st.integers(1, 8))
    # unique slots -> exact readback (no collision averaging)
    slots = data.draw(st.lists(st.integers(0, entries - 1), min_size=n_wf,
                               max_size=n_wf, unique=True))
    vals = data.draw(st.lists(st.floats(0.0, 100.0), min_size=n_wf,
                              max_size=n_wf))
    tbl = PRED.table_init(1, entries)
    tid = jnp.array([0])
    idx = jnp.array([slots])
    v = jnp.array([vals], jnp.float32)
    tbl = PRED.table_update(tbl, tid, idx, v, v, ema=0.5)
    i0, sens, hit = PRED.table_lookup(tbl, tid, idx,
                                      jnp.full((1, n_wf), -1.0),
                                      jnp.full((1, n_wf), -1.0))
    np.testing.assert_allclose(np.asarray(i0[0]), vals, rtol=1e-6, atol=1e-5)
    assert np.all(np.asarray(hit) == 1.0)


@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([16, 32, 64]))
@settings(**SETTINGS)
def test_chunked_ce_matches_full(seed, chunk):
    rng = np.random.default_rng(seed)
    B, S, D, V = 2, 64, 16, 50
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    emb = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.int32)
    got = chunked_ce_loss(x, emb, labels, mask.astype(jnp.float32), chunk=chunk)
    logits = x @ emb.T
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    m = mask.astype(jnp.float32)
    want = ((lse - gold) * m).sum() / jnp.maximum(m.sum(), 1)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-5)


@given(f1=st.floats(1.3, 2.2), f2=st.floats(1.3, 2.2),
       act=st.floats(0.05, 1.0))
@settings(**SETTINGS)
def test_power_bounds(f1, f2, act):
    p = float(PWR.power(jnp.float32(f1), jnp.float32(act)))
    assert 0.0 < p < 5.0
    # higher V/f at same activity costs more (margin for float rounding)
    if f2 > f1 + 1e-3:
        assert float(PWR.power(jnp.float32(f2), jnp.float32(act))) > p


# ---------------------------------------------------------------------------
# v2 fused epoch kernel: the hypothesis sweep re-draws the deterministic
# cases of tests/test_kernels.py (same helpers) across random seeds, odd
# shapes and every mechanism family.
# ---------------------------------------------------------------------------

_EPOCH_SHAPES = [(4, 8, 10), (5, 7, 6), (3, 9, 4), (6, 5, 8)]


@given(seed=st.integers(0, 2**16),
       shape=st.sampled_from(_EPOCH_SHAPES),
       fam=st.integers(0, 4))
@settings(**SETTINGS)
def test_epoch_fused_engines_agree(seed, shape, fam):
    """pallas_call(interpret) engine == direct-eval engine: discrete
    outputs identical, floats at ulp level, for any seed/shape/family."""
    from test_kernels import EPOCH_FAMS, _epoch_case, _flat
    from repro.kernels import epoch_fused as KEF
    CU, WF, NF = shape
    family, fork_est, model = EPOCH_FAMS[fam]
    args, kw = _epoch_case(family, CU, WF, NF=NF, seed=seed,
                           fork_estimator=fork_est, cu_model=model)
    a = KEF.epoch_fused(*args, **kw)
    b = KEF.epoch_fused(*args, **kw, via_pallas=True)
    for x, y in zip(_flat(a), _flat(b)):
        if np.issubdtype(x.dtype, np.integer):
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(x, y, rtol=3e-6, atol=3e-5)


@given(seed=st.integers(0, 2**16),
       shape=st.sampled_from(_EPOCH_SHAPES),
       fam=st.integers(0, 4))
@settings(**SETTINGS)
def test_epoch_fused_invariants_random_state(seed, shape, fam):
    """Waves only move forward, ladder index in range, telemetry finite,
    table counts monotone — from any random carry state."""
    from test_kernels import EPOCH_FAMS, _epoch_case, _flat
    from repro.kernels import epoch_fused as KEF
    CU, WF, NF = shape
    family, fork_est, model = EPOCH_FAMS[fam]
    args, kw = _epoch_case(family, CU, WF, NF=NF, seed=seed,
                           fork_estimator=fork_est, cu_model=model)
    out = KEF.epoch_fused(*args, **kw)
    assert np.all(np.asarray(out.pos) >= np.asarray(args[3]) - 1e-4)
    fidx = np.asarray(out.fidx)
    assert np.all((fidx >= 0) & (fidx < NF))
    assert np.all(np.asarray(out.work) >= 0)
    for leaf in _flat(out):
        assert np.all(np.isfinite(leaf))
    if family == "pc":
        assert np.all(np.asarray(out.table.count)
                      >= np.asarray(kw["table"].count))


_FORK_MECHS = ("stall", "lead", "crit", "crisp", "accreac",
               "pcstall", "accpc")


@given(seed=st.integers(0, 2**16),
       epoch_us=st.sampled_from([1.0, 10.0]),
       mech=st.sampled_from(_FORK_MECHS))
@settings(max_examples=8, deadline=None)
def test_grid_v2_engines_agree_through_run_grid(seed, epoch_us, mech):
    """Grid-v2 mirror of the engine-agreement sweep, driven through the
    REAL dispatch path (run_grid) rather than a bare kernel call: for any
    seed/point/traced mechanism, the fused-kernel engine tracks the jnp
    engine at aggregate tolerance (per-epoch divergence is chaotic — see
    kernels.epoch_fused). The SimStatic is fixed so hypothesis examples
    ride one compiled executable per engine."""
    import dataclasses
    from repro.core.sweep import run_grid
    sim = SimConfig(n_cu=8, n_wf=6, n_epochs=40)
    prog = make_program("pv2", "mixed", seed % 61, P=256)
    pt = {"epoch_us": [epoch_us]}
    a = run_grid([prog], sim, pt, (mech,),
                 seeds=[seed % 97])[(epoch_us,)]["pv2"][mech]
    b = run_grid([prog], dataclasses.replace(sim, use_pallas="v2"), pt,
                 (mech,), seeds=[seed % 97])[(epoch_us,)]["pv2"][mech]
    assert set(a) == set(b)
    for k in ("work", "energy"):
        ra = float(np.sum(np.asarray(a[k])))
        rb = float(np.sum(np.asarray(b[k])))
        assert abs(ra - rb) / abs(ra) < 2e-3, (mech, k, ra, rb)
    fidx_a, fidx_b = np.asarray(a["fidx"]), np.asarray(b["fidx"])
    assert np.mean(fidx_a == fidx_b) > 0.5, mech
