"""Tests for the beyond-paper TPU DVFS integration (arch-derived traces)."""
import numpy as np
import pytest

from repro.configs import TRAIN_4K, DECODE_32K, get_config
from repro.dvfs_runtime.manager import DVFSManager
from repro.dvfs_runtime.telemetry import arch_program, step_ops


@pytest.mark.parametrize("arch", ["llama3-405b", "rwkv6-3b", "qwen2-moe-a2.7b"])
def test_arch_program_wellformed(arch):
    cfg = get_config(arch)
    prog = arch_program(cfg, TRAIN_4K)
    i0 = np.asarray(prog.i0_rate)
    s = np.asarray(prog.sens_rate)
    m = np.asarray(prog.mem_frac)
    assert i0.shape == s.shape == m.shape
    assert np.all(i0 >= 0) and np.all(s >= 0)
    assert np.all((m >= 0) & (m <= 1))
    assert s.max() > 0  # at least one compute-sensitive phase


def test_moe_has_async_collective_phase():
    cfg = get_config("qwen2-moe-a2.7b")
    names = [o[0] for o in step_ops(cfg, TRAIN_4K)]
    assert "moe_a2a" in names and "grad_reduce" in names


def test_decode_trace_differs_from_train():
    cfg = get_config("glm4-9b")
    pt = arch_program(cfg, TRAIN_4K)
    pd = arch_program(cfg, DECODE_32K)
    # decode is far more memory-bound than train
    assert float(np.mean(np.asarray(pd.mem_frac))) > \
        float(np.mean(np.asarray(pt.mem_frac)))


def test_manager_reports_energy_savings():
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    rep = mgr.report()
    assert rep["ed2p_norm"] < 1.0  # objective improves vs static 1.7
    assert 0.5 < rep["energy_norm"] < 1.3
    assert rep["accuracy"] > 0.9  # step programs are highly repetitive
    assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2
