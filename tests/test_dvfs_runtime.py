"""Tests for the beyond-paper TPU DVFS integration (arch-derived traces)."""
import numpy as np
import pytest

from repro.configs import TRAIN_4K, DECODE_32K, get_config
from repro.dvfs_runtime.manager import DVFSManager
from repro.dvfs_runtime.telemetry import arch_program, step_ops


@pytest.mark.parametrize("arch", ["llama3-405b", "rwkv6-3b", "qwen2-moe-a2.7b"])
def test_arch_program_wellformed(arch):
    cfg = get_config(arch)
    prog = arch_program(cfg, TRAIN_4K)
    i0 = np.asarray(prog.i0_rate)
    s = np.asarray(prog.sens_rate)
    m = np.asarray(prog.mem_frac)
    assert i0.shape == s.shape == m.shape
    assert np.all(i0 >= 0) and np.all(s >= 0)
    assert np.all((m >= 0) & (m <= 1))
    assert s.max() > 0  # at least one compute-sensitive phase


def test_moe_has_async_collective_phase():
    cfg = get_config("qwen2-moe-a2.7b")
    names = [o[0] for o in step_ops(cfg, TRAIN_4K)]
    assert "moe_a2a" in names and "grad_reduce" in names


def test_decode_trace_differs_from_train():
    cfg = get_config("glm4-9b")
    pt = arch_program(cfg, TRAIN_4K)
    pd = arch_program(cfg, DECODE_32K)
    # decode is far more memory-bound than train
    assert float(np.mean(np.asarray(pd.mem_frac))) > \
        float(np.mean(np.asarray(pt.mem_frac)))


def test_manager_reports_energy_savings():
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    rep = mgr.report()
    assert rep["ed2p_norm"] < 1.0  # objective improves vs static 1.7
    assert 0.5 < rep["energy_norm"] < 1.3
    assert rep["accuracy"] > 0.9  # step programs are highly repetitive
    assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2


def test_manager_report_well_formed_and_jit_cached():
    """report(): freq_timeshare is a distribution, metrics are finite, and
    repeated calls dispatch cached executables (no re-trace)."""
    from repro.core import power as PWR
    from repro.core import sweep as SW
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    rep = mgr.report()
    # one histogram bin per V/f state of the simulator's ladder
    assert len(rep["freq_timeshare"]) == len(PWR.FREQS_GHZ)
    assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2
    assert all(x >= 0.0 for x in rep["freq_timeshare"])
    assert np.isfinite(rep["ed2p_norm"]) and np.isfinite(rep["accuracy"])
    before = dict(SW.TRACE_COUNTS)
    rep2 = mgr.report()
    assert dict(SW.TRACE_COUNTS) == before  # jit cache hit: no new compile
    assert rep2["ed2p_norm"] == pytest.approx(rep["ed2p_norm"])
    assert rep2["accuracy"] == pytest.approx(rep["accuracy"])


def test_manager_step_time_stats_in_reports():
    """observe_step records (step, seconds) PAIRS — the step index is not
    dropped — and step-time stats reach both report() and every
    grid_report() row (with mean_step_s kept as a back-compat alias)."""
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    for step, dt in ((10, 0.02), (20, 0.04), (40, 0.06)):
        mgr.observe_step(step, dt)
    assert mgr.step_log == [(10, 0.02), (20, 0.04), (40, 0.06)]
    rep = mgr.report()
    st = rep["step_time"]
    assert st["n_steps"] == 3
    assert (st["first_step"], st["last_step"]) == (10, 40)
    assert st["mean_step_s"] == pytest.approx(0.04)
    assert st["p50_step_s"] == pytest.approx(0.04)
    assert st["p50_step_s"] <= st["p99_step_s"] <= 0.06 + 1e-12
    assert rep["mean_step_s"] == pytest.approx(0.04)  # back-compat alias
    for row in mgr.grid_report(epoch_us=(1.0, 10.0)).values():
        assert row["step_time"]["n_steps"] == 3
        assert row["mean_step_s"] == pytest.approx(0.04)


def test_manager_empty_step_log():
    """No telemetry observed: stats are well-formed zeros, not NaN."""
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    rep = mgr.report()
    assert rep["mean_step_s"] == 0.0
    assert rep["step_time"]["n_steps"] == 0
    assert rep["step_time"]["first_step"] == -1


def test_manager_grid_report():
    """grid_report sweeps (epoch_us x objective) in one executable family
    and returns a well-formed report per grid point."""
    cfg = get_config("glm4-9b")
    mgr = DVFSManager.for_model(cfg, TRAIN_4K, n_cu=8)
    reps = mgr.grid_report(epoch_us=(1.0, 10.0),
                           objectives=("ed2p", "perfcap05"))
    assert set(reps) == {(1.0, "ed2p"), (1.0, "perfcap05"),
                         (10.0, "ed2p"), (10.0, "perfcap05")}
    for rep in reps.values():
        assert np.isfinite(rep["ed2p_norm"])
        assert abs(sum(rep["freq_timeshare"]) - 1.0) < 1e-2
    # the 1-point report matches the matching grid point
    one = mgr.report()
    assert one["ed2p_norm"] == pytest.approx(
        reps[(1.0, "ed2p")]["ed2p_norm"])
