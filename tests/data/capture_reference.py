"""Capture bitwise reference traces for the mechanism-dispatch contract.

Run from the repo root (``PYTHONPATH=src python tests/data/capture_reference.py``)
at a known-good commit to (re)generate ``grid_reference.npz``:
``tests/test_mechanisms.py`` replays the same grids through the current
dispatch path and asserts bitwise equality when the capturing platform
matches (jax version + backend recorded in the file), to 1e-5 otherwise.

The captured grids cover every pre-existing mechanism through both entry
points and the axes the spec-driven dedup reasons about:

  * ``suite``    — 1-point run_suite, all 11 mechanisms;
  * ``grid2x2``  — (epoch_us x objective) figure grid, all 11 mechanisms;
  * ``gridema``  — a table_ema-only axis, fork mechanisms + a static
                   baseline (the axis reactive mechanisms dedup across).
"""
import json
from pathlib import Path

import jax
import numpy as np

from repro.core.simulate import MECHANISMS, SimConfig
from repro.core.sweep import run_grid

OUT = Path(__file__).resolve().parent / "grid_reference.npz"
SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=48)
WORKLOADS = ("comd", "xsbench")
EMA_MECHS = ("static17", "crisp", "accreac", "pcstall", "accpc", "oracle")

CASES = {
    "suite": {"epoch_us": [1.0]},
    "grid2x2": {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]},
    "gridema": {"table_ema": [0.3, 0.5]},
}


def case_mechs(case: str):
    return EMA_MECHS if case == "gridema" else MECHANISMS


def run_case(case: str):
    from repro.core.workloads import get_workload
    progs = {w: get_workload(w) for w in WORKLOADS}
    return run_grid(progs, SIM, CASES[case], case_mechs(case))


def main() -> None:
    arrays = {}
    for case in CASES:
        res = run_case(case)
        for key, by_wl in res.items():
            for wl, by_mech in by_wl.items():
                for mech, tr in by_mech.items():
                    for ch, v in tr.items():
                        arrays[f"{case}|{key!r}|{wl}|{mech}|{ch}"] = v
    meta = {"jax": jax.__version__,
            "backend": jax.default_backend(),
            "n_dev": jax.local_device_count(),
            "note": "bitwise reference for the mechanism dispatch contract"}
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    np.savez_compressed(OUT, **arrays)
    print(f"wrote {OUT} ({OUT.stat().st_size / 1024:.0f} KiB, "
          f"{len(arrays) - 1} arrays, meta={meta})")


if __name__ == "__main__":
    main()
