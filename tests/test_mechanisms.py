"""Tests for the MechanismSpec registry and the spec-driven dispatch
contract: traced-id stability (ids are part of the bitwise contract),
spec validation, name<->spec resolution, registration errors, bitwise
equivalence of every pre-existing mechanism against captured pre-redesign
reference traces, the generic exec_axes dedup (a table_ema-only grid axis
must stop multiplying reactive-mechanism rows), and end-to-end custom
mechanism registration without engine edits."""
import dataclasses
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import mechanisms as MECH
from repro.core import sweep as SW
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import (FORK_MECH_IDS, FORK_MECHS, MECHANISMS,
                                 SimAxes, SimConfig, predict_instr, run_sim)
from repro.core.sweep import STATIC_EXEC_AXES, run_grid, run_suite
from repro.core.workloads import get_workload

SIM = SimConfig(n_cu=16, n_wf=12, n_epochs=48)
WORKLOADS = ("comd", "xsbench")
# the engine-imposed live-axis floor for predicting (non-static) specs
# ("power" — the traced IVR regime — is live for every family)
FULL_AXES = ("epoch_us", "sigma", "cap_per_ghz", "membw", "obj", "n_ep",
             "power")


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


def _assert_cross_dispatch(got, want, ctx):
    """Compare results of two DIFFERENT dispatches (different flat-axis
    lengths). On one device this is empirically bitwise; on a forced
    multi-device mesh the flat axis shards to different per-device batch
    shapes and XLA compiles per shape — since the power params became
    traced operands (PR 5) those compilations can differ at the last ulp,
    so the comparison degrades to 1e-5 there. Broadcast-within-one-
    dispatch comparisons stay bitwise unconditionally."""
    if jax.local_device_count() == 1:
        np.testing.assert_array_equal(got, want, err_msg=ctx)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=ctx)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_traced_ids_are_stable():
    """The builtin traced ids are part of the bitwise dispatch contract
    (the sweep layer vmaps executables over them and the scan body's
    branch selects compare against them): renumbering is a compiled-graph
    change and MUST fail loudly here."""
    want = {"stall": 0, "lead": 1, "crit": 2, "crisp": 3, "accreac": 4,
            "pcstall": 5, "accpc": 6, "oracle": 7}
    got = {s.name: s.traced_id for s in MECH.fork_specs()}
    assert got == want
    assert FORK_MECHS == tuple(want)
    assert FORK_MECH_IDS == want
    assert MECHANISMS == MECH.BUILTIN_NAMES
    # the engine's branch constants derive from these ids
    assert MECH.traced_reactive_count() == 5


def test_builtin_families_and_flags():
    fams = {s.name: s.family for s in MECH.specs()}
    assert fams == {"static13": "static", "static17": "static",
                    "static22": "static", "stall": "reactive",
                    "lead": "reactive", "crit": "reactive",
                    "crisp": "reactive", "accreac": "reactive",
                    "pcstall": "pc", "accpc": "pc", "oracle": "oracle"}
    assert MECH.get("static17").static_fidx == 4
    assert MECH.get("pcstall").hit_telemetry
    assert MECH.get("accpc").hit_telemetry
    assert not MECH.get("crisp").hit_telemetry
    # dedup contract: statics ignore objective+table_ema, reactive/oracle
    # ignore table_ema, pc mechanisms consume everything; the power regime
    # is live for EVERY family (ladder + energy accounting read it)
    assert STATIC_EXEC_AXES == ("epoch_us", "sigma", "cap_per_ghz", "membw",
                                "power")
    assert "table_ema" not in MECH.get("crisp").exec_axes
    assert "table_ema" not in MECH.get("oracle").exec_axes
    assert "table_ema" in MECH.get("pcstall").exec_axes
    for name in MECH.BUILTIN_NAMES:
        assert "power" in MECH.get(name).exec_axes, name


def test_exec_axes_validated_against_sim_axes():
    assert MECH.SIM_AXES_FIELDS == SimAxes._fields
    with pytest.raises(AssertionError, match="not SimAxes fields"):
        MechanismSpec("bad", "reactive", ("epoch_us", "nope"),
                      predict=lambda *a: None)
    # canonicalization: declaration order does not matter
    a = MechanismSpec("x", "reactive", tuple(reversed(FULL_AXES)),
                      predict=lambda *a: None)
    assert a.exec_axes == FULL_AXES
    assert a.config_axes == ("epoch_us", "sigma", "cap_per_ghz", "membw",
                             "objective", "n_epochs", "power")
    assert a.dedup_axes == ("epoch_us", "sigma", "cap_per_ghz", "membw",
                            "objective", "power")


def test_exec_axes_enforce_engine_imposed_liveness():
    """exec_axes may over-declare liveness but never omit an axis the
    engine unconditionally reads — an omitted live axis would make the
    grid dedup broadcast wrong results (e.g. a pc-family spec without
    table_ema would collapse a table_ema grid while the forced table
    maintenance genuinely depends on it)."""
    with pytest.raises(ValueError, match="live axes.*table_ema"):
        MechanismSpec("bad", "pc", FULL_AXES, predict=lambda *a: None)
    with pytest.raises(ValueError, match="live axes.*obj"):
        MechanismSpec("bad", "reactive",
                      ("epoch_us", "sigma", "cap_per_ghz", "membw", "n_ep",
                       "power"),
                      predict=lambda *a: None)
    # the power regime is engine-imposed for every family: the ladder and
    # the energy accounting read it even for a static frequency
    with pytest.raises(ValueError, match="live axes.*power"):
        MechanismSpec("bad", "reactive",
                      ("epoch_us", "sigma", "cap_per_ghz", "membw", "obj",
                       "n_ep"),
                      predict=lambda *a: None)
    with pytest.raises(ValueError, match="live axes"):
        MechanismSpec("bad", "static", ("epoch_us", "sigma"), static_fidx=0)
    # every builtin satisfies its own floor by construction
    for s in MECH.specs():
        MechanismSpec(s.name, s.family, s.exec_axes, static_fidx=s.static_fidx,
                      traced_id=s.traced_id, cu_model=s.cu_model,
                      fork_estimator=s.fork_estimator,
                      hit_telemetry=s.hit_telemetry)


def test_spec_validation_errors():
    with pytest.raises(AssertionError, match="family"):
        MechanismSpec("bad", "quantum", ("epoch_us",))
    with pytest.raises(AssertionError, match="static_fidx"):
        MechanismSpec("bad", "static", ("epoch_us",))  # missing fidx
    with pytest.raises(AssertionError, match="static_fidx"):
        MechanismSpec("bad", "static", ("epoch_us",), static_fidx=99)
    with pytest.raises(AssertionError, match="must not set static_fidx"):
        MechanismSpec("bad", "reactive", ("epoch_us",), static_fidx=1,
                      predict=lambda *a: None)
    with pytest.raises(AssertionError, match="update hook requires"):
        MechanismSpec("bad", "reactive", ("epoch_us",),
                      update=lambda *a: None)


def test_name_spec_round_trip():
    spec = MECH.get("pcstall")
    assert MECH.resolve("pcstall") is spec
    assert MECH.resolve(spec) is spec
    assert spec.label == "PCSTALL (predictive)"
    with pytest.raises(KeyError, match="unknown mechanism"):
        MECH.get("not_a_mechanism")
    with pytest.raises(KeyError, match="unknown mechanism"):
        MECH.resolve("not_a_mechanism")


def test_resolve_rejects_impostor_specs():
    """A spec reusing a registered name but differing in fields must not
    silently substitute (or be substituted by) the registry entry, and an
    unregistered spec cannot forge a traced id to ride a builtin path."""
    fake = dataclasses.replace(MECH.get("crisp"), cu_model="stall")
    with pytest.raises(ValueError, match="differs from the registered"):
        MECH.resolve(fake)
    forged = MechanismSpec("impostor", "pc", MECH.get("pcstall").exec_axes,
                           traced_id=6)  # constructible (looks builtin)
    with pytest.raises(AssertionError, match="traced ids are reserved"):
        MECH.resolve(forged)
    # an unregistered spec with its own name and hooks resolves to itself
    own = _toy_spec("never_registered")
    assert MECH.resolve(own) is own


def test_hit_telemetry_requires_pc_family():
    """The flag promises a hit_rate channel only the PC-table path emits;
    declaring it elsewhere must fail at construction, not unpack time."""
    with pytest.raises(ValueError, match="hit_telemetry requires"):
        _toy_spec("bad_flag", hit_telemetry=True)  # reactive family
    with pytest.raises(ValueError, match="needs a predict hook"):
        MechanismSpec("bad_pc", "pc", ("epoch_us", "table_ema"))


def test_duplicate_and_reserved_registration():
    pc_axes = FULL_AXES + ("table_ema",)
    with pytest.raises(ValueError, match="already registered"):
        MECH.register(MechanismSpec("pcstall", "pc", pc_axes,
                                    predict=lambda *a: None))
    # builtins cannot be overridden even explicitly
    with pytest.raises(ValueError, match="already registered"):
        MECH.register(MechanismSpec("pcstall", "pc", pc_axes,
                                    predict=lambda *a: None),
                      allow_override=True)
    # traced ids are reserved for the builtin fork family
    with pytest.raises(AssertionError, match="traced ids are reserved"):
        MECH.register(MechanismSpec("mine", "reactive", FULL_AXES,
                                    traced_id=9, predict=lambda *a: None))
    # custom predictor families need a predict hook (enforced at
    # construction: without one the spec would trace a builtin path)
    with pytest.raises(ValueError, match="needs a predict hook"):
        MechanismSpec("mine", "reactive", FULL_AXES)
    with pytest.raises(AssertionError, match="cannot unregister builtin"):
        MECH.unregister("oracle")
    # user registrations CAN be replaced with allow_override, and removed
    # (verify_axes=False: the dummy hook is never meant to trace — the
    # registration-time audit would otherwise abstract-eval it)
    try:
        MECH.register(MechanismSpec("tmp_dup", "reactive", FULL_AXES,
                                    predict=lambda *a: None),
                      verify_axes=False)
        with pytest.raises(ValueError, match="already registered"):
            MECH.register(MechanismSpec("tmp_dup", "reactive", FULL_AXES,
                                        predict=lambda *a: None),
                          verify_axes=False)
        MECH.register(MechanismSpec("tmp_dup", "reactive", FULL_AXES,
                                    predict=lambda *a: None),
                      allow_override=True, verify_axes=False)
    finally:
        MECH.unregister("tmp_dup")
    assert "tmp_dup" not in MECH.names()


def test_mechanism_table_lists_registry():
    table = MECH.mechanism_table()
    for name in MECH.BUILTIN_NAMES:
        assert f"`{name}`" in table


# ---------------------------------------------------------------------------
# Bitwise contract vs captured pre-redesign references
# ---------------------------------------------------------------------------


def _reference():
    path = Path(__file__).parent / "data" / "grid_reference.npz"
    ref = np.load(path)
    meta = json.loads(bytes(ref["__meta__"]))
    exact = (meta["jax"] == jax.__version__
             and meta["backend"] == jax.default_backend()
             and meta["n_dev"] == jax.local_device_count())
    return ref, exact


@pytest.mark.parametrize("case", ["suite", "grid2x2", "gridema"])
def test_bitwise_vs_captured_reference(case):
    """Acceptance: every pre-existing mechanism produces bitwise-identical
    run_grid/run_suite traces through the spec-driven dispatch, verified
    against references captured before the redesign
    (tests/data/capture_reference.py). The gridema case exercises the NEW
    reactive/oracle dedup across a table_ema-only axis — broadcast class
    traces must still reproduce the pre-dedup per-point traces bitwise.
    On a platform other than the capturing one (jax version, backend and
    local device count recorded in the file — a forced multi-device mesh
    shards the flat axis differently) XLA codegen may differ at the last
    ulp, so the comparison degrades to 1e-5."""
    import sys
    sys.path.insert(0, str(Path(__file__).parent / "data"))
    try:
        from capture_reference import run_case
    finally:
        sys.path.pop(0)
    ref, exact = _reference()
    res = run_case(case)
    n = 0
    for key, by_wl in res.items():
        for wl, by_mech in by_wl.items():
            for mech, tr in by_mech.items():
                for ch, v in tr.items():
                    k = f"{case}|{key!r}|{wl}|{mech}|{ch}"
                    if exact:
                        np.testing.assert_array_equal(
                            np.asarray(v), ref[k], err_msg=k)
                    else:
                        np.testing.assert_allclose(
                            np.asarray(v), ref[k], rtol=1e-5, atol=1e-5,
                            err_msg=k)
                    n += 1
    assert n == sum(1 for k in ref.files if k.startswith(case + "|"))


# ---------------------------------------------------------------------------
# Generic exec_axes dedup (the ROADMAP's reactive/table_ema item)
# ---------------------------------------------------------------------------


def test_reactive_dedup_on_table_ema_axis(progs):
    """Acceptance: a table_ema-only grid axis no longer multiplies
    reactive-mechanism rows — they scan once per class and broadcast —
    while PC mechanisms (whose exec_axes include table_ema) still span
    every point, and all results stay bitwise-equal to per-point
    run_suite."""
    sim = dataclasses.replace(SIM, n_cu=12)  # SimStatic unique to this test
    grid = {"table_ema": [0.3, 0.5, 0.7]}
    W, G = len(WORKLOADS), 3
    SW.reset_counters()
    res = run_grid(progs, sim, grid, ("crisp", "accreac", "pcstall",
                                      "oracle"))
    # reactive group: W x 1 class x 2 mechs; pc group: W x G x 1 mech
    assert SW.DISPATCH_ROWS["grid_forks"] == W * 1 * 2 + W * G * 1
    assert SW.DISPATCH_ROWS["grid_oracle"] == W * 1  # oracle dedups too
    # the broadcast class trace is bitwise-identical across member keys
    for wl in WORKLOADS:
        for m in ("crisp", "accreac", "oracle"):
            a = res[(0.3,)][wl][m]
            for ema in (0.5, 0.7):
                b = res[(ema,)][wl][m]
                for k in a:
                    np.testing.assert_array_equal(a[k], b[k],
                                                  err_msg=f"{ema}/{wl}/{m}/{k}")
    # and every point reproduces its per-point run_suite (bitwise on one
    # device; see _assert_cross_dispatch) — pc mechanisms genuinely
    # differ across ema values and stay exact
    for ema in (0.3, 0.5, 0.7):
        suite = run_suite(progs, dataclasses.replace(sim, table_ema=ema),
                          ("crisp", "accreac", "pcstall", "oracle"))
        for wl in WORKLOADS:
            for m in ("crisp", "accreac", "pcstall", "oracle"):
                for k, v in suite[wl][m].items():
                    _assert_cross_dispatch(res[(ema,)][wl][m][k], v,
                                           f"{ema}/{wl}/{m}/{k}")
    # pcstall results must actually vary with the EMA (the axis is live)
    assert not np.array_equal(res[(0.3,)]["comd"]["pcstall"]["work"],
                              res[(0.7,)]["comd"]["pcstall"]["work"])


def test_dedup_flag_disables_collapsing(progs):
    """dedup=False forces one scan per (mechanism x grid point) — the A/B
    baseline the grid_ema benchmark times — with identical results."""
    sim = dataclasses.replace(SIM, n_cu=12, n_epochs=24)
    grid = {"table_ema": [0.3, 0.5]}
    W, G = len(WORKLOADS), 2
    a = run_grid(progs, sim, grid, ("crisp",))
    SW.reset_counters()
    b = run_grid(progs, sim, grid, ("crisp",), dedup=False)
    assert SW.DISPATCH_ROWS["grid_forks"] == W * G
    for key in a:
        for wl in WORKLOADS:
            for k in a[key][wl]["crisp"]:
                _assert_cross_dispatch(a[key][wl]["crisp"][k],
                                       b[key][wl]["crisp"][k],
                                       f"{key}/{wl}/{k}")


# ---------------------------------------------------------------------------
# Custom mechanism registration, end to end
# ---------------------------------------------------------------------------


def _toy_spec(name="toy_blend", family="reactive", extra_axes=(), **kw):
    from repro.core import estimators as EST

    def predict(carry, ctx, st, ax):
        i0 = 0.5 * ctx.i0_l.sum(-1) + 0.5 * carry.react_i0
        sens = 0.5 * ctx.s_l.sum(-1) + 0.5 * carry.react_sens
        return predict_instr(i0, sens, st, ax)

    def update(counters, f_sel, I_f, carry, ctx, st, ax):
        i0_cu, s_cu = EST.cu_estimate(counters, f_sel, "crisp")
        return i0_cu / ax.epoch_us, s_cu / ax.epoch_us

    return MechanismSpec(
        name, family,
        exec_axes=("epoch_us", "sigma", "cap_per_ghz", "membw", "obj",
                   "n_ep", "power") + tuple(extra_axes),
        label="toy static+dynamic blend", predict=predict, update=update,
        **kw)


def test_custom_mechanism_through_engine_and_grid(progs):
    """A registered mechanism runs through run_sim AND the sharded grid
    dispatch with no engine/sweep edits, produces the standard trace
    schema, dedups by its declared exec_axes, and its name works
    everywhere a builtin's does."""
    spec = MECH.register(_toy_spec())
    try:
        tr = run_sim(progs["comd"], SIM, "toy_blend")
        assert set(tr) == {"work", "energy", "err", "fidx", "true_sens"}
        assert tr["work"].shape == (SIM.n_epochs, SIM.n_cu)
        assert np.all(np.isfinite(tr["work"]))
        # a real prediction: finite nonneg error, mechanism actually picks
        # varied frequencies once warmed up
        assert np.unique(tr["fidx"]).size > 1
        SW.reset_counters()
        grid = run_grid(progs, SIM, {"table_ema": [0.3, 0.5]},
                        ("toy_blend",))
        # table-free by declaration: one class, rows not multiplied
        assert SW.DISPATCH_ROWS["grid_toy_blend"] == len(WORKLOADS)
        for wl in WORKLOADS:
            a = grid[(0.3,)][wl]["toy_blend"]
            b = grid[(0.5,)][wl]["toy_blend"]
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
            # grid path == specialized serial path (same spec, same
            # executable family contract as every builtin)
            ser = run_sim(progs[wl], dataclasses.replace(SIM, table_ema=0.3),
                          spec)
            for k in ser:
                np.testing.assert_allclose(a[k], ser[k], rtol=1e-5,
                                           atol=1e-5, err_msg=f"{wl}/{k}")
    finally:
        MECH.unregister("toy_blend")


def test_custom_mechanism_hit_telemetry_flag(progs):
    """A registered spec that declares hit_telemetry keeps the channel
    through the sweep layer without any sweep edit (satellite: the old
    _PC_MECHS-keyed filter is gone)."""
    spec = _toy_spec("toy_pc", family="pc", extra_axes=("table_ema",),
                     hit_telemetry=True)
    MECH.register(spec)
    try:
        suite = run_suite(progs, SIM, ("toy_pc", "pcstall", "crisp"))
        for wl in WORKLOADS:
            assert "hit_rate" in suite[wl]["pcstall"]
            assert "hit_rate" not in suite[wl]["crisp"]
            # custom pc-family spec: channel present iff declared
            assert "hit_rate" in suite[wl]["toy_pc"]
    finally:
        MECH.unregister("toy_pc")
