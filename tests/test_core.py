"""Unit tests for the DVFS core: power model, estimators, predictors,
controller, metric math."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core.estimators import cu_estimate, wf_stall_estimate
from repro.core.simulate import SimConfig, ednp, epoch_execute
from repro.core.workloads import get_workload, make_program


def test_power_monotone_in_frequency():
    f = PWR.FREQS_GHZ
    p = PWR.power(f, jnp.full_like(f, 0.5))
    assert bool(jnp.all(jnp.diff(p) > 0))


def test_voltage_range():
    assert float(PWR.v_of_f(1.3)) == pytest.approx(0.70)
    assert float(PWR.v_of_f(2.2)) == pytest.approx(1.00)


def test_transition_energy_symmetric_and_zero_at_fixpoint():
    assert float(PWR.transition_energy(1.7, 1.7)) == 0.0
    assert float(PWR.transition_energy(1.3, 2.2)) == pytest.approx(
        float(PWR.transition_energy(2.2, 1.3)))


def test_transition_latency_schedule():
    # paper §5: 4ns @ 1us ... 400ns cap @ >=100us
    assert PWR.transition_latency_us(1.0) == pytest.approx(4e-3)
    assert PWR.transition_latency_us(10.0) == pytest.approx(4e-2)
    assert PWR.transition_latency_us(100.0) == pytest.approx(0.4)
    assert PWR.transition_latency_us(1000.0) == pytest.approx(0.4)


def test_pc_table_update_then_lookup_roundtrip():
    tbl = PRED.table_init(2, 16)
    tid = jnp.array([0, 1])
    idx = jnp.array([[3, 3], [5, 7]])
    i0 = jnp.array([[10.0, 14.0], [5.0, 6.0]])
    sens = jnp.array([[1.0, 3.0], [2.0, 4.0]])
    tbl = PRED.table_update(tbl, tid, idx, i0, sens, ema=0.5)
    # collisions in epoch 0 average (slot (0,3) gets mean of 10,14)
    fb = jnp.zeros((2, 2))
    li0, lsens, hit = PRED.table_lookup(tbl, tid, idx, fb, fb)
    np.testing.assert_allclose(np.asarray(li0[0]), [12.0, 12.0])
    np.testing.assert_allclose(np.asarray(lsens[1]), [2.0, 4.0])
    assert np.all(np.asarray(hit) == 1.0)


def test_pc_table_miss_falls_back():
    tbl = PRED.table_init(1, 8)
    tid = jnp.array([0])
    idx = jnp.array([[2]])
    fb_i0 = jnp.array([[42.0]])
    fb_sens = jnp.array([[7.0]])
    i0, sens, hit = PRED.table_lookup(tbl, tid, idx, fb_i0, fb_sens)
    assert float(i0[0, 0]) == 42.0 and float(sens[0, 0]) == 7.0
    assert float(hit[0, 0]) == 0.0


def test_sensitivity_commutativity():
    """Paper §4.2: domain sensitivity == sum of wavefront sensitivities.
    Verified on the exact fork-based linear fit."""
    import jax
    prog = get_workload("comd")
    sim = SimConfig(n_cu=4, n_wf=8)
    pos = jnp.abs(jnp.asarray(
        np.random.default_rng(0).uniform(0, 4000, (4, 8)), jnp.float32))
    F = PWR.FREQS_GHZ
    c_f = jax.vmap(lambda f: epoch_execute(prog, pos, jnp.full((4,), f),
                                           sim)[1]["steady"])(F)
    sens_wf = (c_f[-1] - c_f[0]) / (F[-1] - F[0])    # (CU,WF)
    I_cu = c_f.sum(-1)
    sens_cu = (I_cu[-1] - I_cu[0]) / (F[-1] - F[0])
    np.testing.assert_allclose(np.asarray(sens_wf.sum(-1)),
                               np.asarray(sens_cu), rtol=1e-5)


def test_wf_stall_estimator_recovers_sensitivity():
    """In an uncontended, un-thrashed epoch the WF STALL estimate is ~exact
    (modulo the 1/16 stall-counter quantization)."""
    prog = make_program("t", "constant", 3)
    sim = SimConfig(n_cu=2, n_wf=4, sigma=0.0, membw=1e12)
    pos = jnp.zeros((2, 4), jnp.float32)
    f = jnp.full((2,), 1.7)
    _, ctr = epoch_execute(prog, pos, f, sim)
    import jax
    F = PWR.FREQS_GHZ
    c_f = jax.vmap(lambda ff: epoch_execute(prog, pos, jnp.full((2,), ff),
                                            sim)[1]["steady"])(F)
    true_sens = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
    ctr = dict(ctr, committed=ctr["steady"])
    _, est = wf_stall_estimate(ctr, f)
    np.testing.assert_allclose(np.asarray(est), np.asarray(true_sens),
                               rtol=0.15)


def test_cu_models_all_finite_and_ordered_inputs():
    prog = get_workload("lulesh")
    sim = SimConfig(n_cu=4, n_wf=8)
    pos = jnp.asarray(np.random.default_rng(1).uniform(0, 4000, (4, 8)),
                      jnp.float32)
    _, ctr = epoch_execute(prog, pos, jnp.full((4,), 1.7), sim)
    ctr = dict(ctr, committed=ctr["steady"])
    for model in ("stall", "lead", "crit", "crisp"):
        i0, sens = cu_estimate(ctr, jnp.full((4,), 1.7), model)
        assert bool(jnp.all(jnp.isfinite(i0))) and bool(jnp.all(jnp.isfinite(sens)))
        assert bool(jnp.all(i0 >= 0))


def test_ednp_math():
    tr = {"work": np.ones((10, 2)) * 5.0, "energy": np.ones((10, 2)) * 2.0}
    E, D, M = ednp(tr, work_budget=50.0, epoch_us=1.0, n=2)
    assert D == pytest.approx(5.0)
    assert E == pytest.approx(20.0)
    assert M == pytest.approx(20.0 * 25.0)


def test_fork_determinism():
    """Same state + same frequency -> bit-identical epoch (the fork
    property the paper's methodology needs, §5.1)."""
    prog = get_workload("hacc")
    sim = SimConfig(n_cu=4, n_wf=8)
    pos = jnp.asarray(np.random.default_rng(2).uniform(0, 4000, (4, 8)),
                      jnp.float32)
    f = jnp.full((4,), 1.9)
    a, _ = epoch_execute(prog, pos, f, sim)
    b, _ = epoch_execute(prog, pos, f, sim)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
