"""Per-kernel allclose sweeps (shapes x dtypes) against the pure-jnp oracles,
kernels executed in Pallas interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 4, 1, 128),   # MQA
    (2, 512, 2, 2, 32),
])
def test_flash_attention_sweep(B, S, H, Hkv, hd, dtype):
    q = _rand((B, S, H, hd), dtype)
    k = _rand((B, S, Hkv, hd), dtype)
    v = _rand((B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    q = _rand((1, 256, 2, 64), jnp.float32)
    k = _rand((1, 256, 2, 64), jnp.float32)
    v = _rand((1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("T,E,CU,WF", [(4, 64, 8, 16), (8, 128, 16, 40)])
def test_pc_table_predict_sweep(T, E, CU, WF):
    ti0 = jnp.asarray(RNG.uniform(0, 60, (T, E)), jnp.float32)
    tse = jnp.asarray(RNG.uniform(0, 40, (T, E)), jnp.float32)
    tcnt = jnp.asarray((RNG.uniform(size=(T, E)) > 0.4).astype(np.float32))
    tid = jnp.asarray(RNG.integers(0, T, CU), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, E, (CU, WF)), jnp.int32)
    fb0 = jnp.asarray(RNG.uniform(0, 60, (CU, WF)), jnp.float32)
    fbs = jnp.asarray(RNG.uniform(0, 40, (CU, WF)), jnp.float32)
    freqs = jnp.linspace(1.3, 2.2, 10)
    out = ops.pc_table_predict(ti0, tse, tcnt, tid, idx, fb0, fbs, freqs)
    want = ref.pc_table_predict_ref(ti0, tse, tcnt, tid, idx, fb0, fbs, freqs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("BH,Tn,hd,chunk", [
    (2, 128, 64, 64), (1, 256, 64, 128), (3, 128, 32, 32),
])
def test_rwkv_chunked_sweep(BH, Tn, hd, chunk):
    r = _rand((BH, Tn, hd), jnp.float32) * 0.5
    k = _rand((BH, Tn, hd), jnp.float32) * 0.5
    v = _rand((BH, Tn, hd), jnp.float32) * 0.5
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (BH, Tn, hd)), jnp.float32)
    u = _rand((BH, hd), jnp.float32) * 0.1
    out = ops.rwkv_chunked(r, k, v, w, u, chunk=chunk)
    want = jax.vmap(lambda a, b, c, d, e: ref.rwkv_chunk_ref(
        a, b, c, d, e, jnp.zeros((hd, hd)))[0])(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_chunk_invariance():
    """Chunk size must not change the result (state carry correctness)."""
    BH, Tn, hd = 1, 128, 32
    r = _rand((BH, Tn, hd), jnp.float32)
    k = _rand((BH, Tn, hd), jnp.float32)
    v = _rand((BH, Tn, hd), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.9, 0.999, (BH, Tn, hd)), jnp.float32)
    u = _rand((BH, hd), jnp.float32) * 0.1
    a = ops.rwkv_chunked(r, k, v, w, u, chunk=32)
    b = ops.rwkv_chunked(r, k, v, w, u, chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
