"""Per-kernel allclose sweeps (shapes x dtypes) against the pure-jnp oracles,
kernels executed in Pallas interpret mode; plus the v2 fused epoch kernel
(engine agreement, exact-mode scan equivalence, table-map semantics)."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mechanisms as MECH
from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core import simulate as SIM
from repro.core.simulate import SimConfig, run_sim
from repro.core.workloads import get_workload, make_program
from repro.kernels import epoch_fused as KEF
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 4, 1, 128),   # MQA
    (2, 512, 2, 2, 32),
])
def test_flash_attention_sweep(B, S, H, Hkv, hd, dtype):
    q = _rand((B, S, H, hd), dtype)
    k = _rand((B, S, Hkv, hd), dtype)
    v = _rand((B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    q = _rand((1, 256, 2, 64), jnp.float32)
    k = _rand((1, 256, 2, 64), jnp.float32)
    v = _rand((1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("T,E,CU,WF", [(4, 64, 8, 16), (8, 128, 16, 40)])
def test_pc_table_predict_sweep(T, E, CU, WF):
    ti0 = jnp.asarray(RNG.uniform(0, 60, (T, E)), jnp.float32)
    tse = jnp.asarray(RNG.uniform(0, 40, (T, E)), jnp.float32)
    tcnt = jnp.asarray((RNG.uniform(size=(T, E)) > 0.4).astype(np.float32))
    tid = jnp.asarray(RNG.integers(0, T, CU), jnp.int32)
    idx = jnp.asarray(RNG.integers(0, E, (CU, WF)), jnp.int32)
    fb0 = jnp.asarray(RNG.uniform(0, 60, (CU, WF)), jnp.float32)
    fbs = jnp.asarray(RNG.uniform(0, 40, (CU, WF)), jnp.float32)
    freqs = jnp.linspace(1.3, 2.2, 10)
    out = ops.pc_table_predict(ti0, tse, tcnt, tid, idx, fb0, fbs, freqs)
    want = ref.pc_table_predict_ref(ti0, tse, tcnt, tid, idx, fb0, fbs, freqs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("BH,Tn,hd,chunk", [
    (2, 128, 64, 64), (1, 256, 64, 128), (3, 128, 32, 32),
])
def test_rwkv_chunked_sweep(BH, Tn, hd, chunk):
    r = _rand((BH, Tn, hd), jnp.float32) * 0.5
    k = _rand((BH, Tn, hd), jnp.float32) * 0.5
    v = _rand((BH, Tn, hd), jnp.float32) * 0.5
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (BH, Tn, hd)), jnp.float32)
    u = _rand((BH, hd), jnp.float32) * 0.1
    out = ops.rwkv_chunked(r, k, v, w, u, chunk=chunk)
    want = jax.vmap(lambda a, b, c, d, e: ref.rwkv_chunk_ref(
        a, b, c, d, e, jnp.zeros((hd, hd)))[0])(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_chunk_invariance():
    """Chunk size must not change the result (state carry correctness)."""
    BH, Tn, hd = 1, 128, 32
    r = _rand((BH, Tn, hd), jnp.float32)
    k = _rand((BH, Tn, hd), jnp.float32)
    v = _rand((BH, Tn, hd), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.9, 0.999, (BH, Tn, hd)), jnp.float32)
    u = _rand((BH, hd), jnp.float32) * 0.1
    a = ops.rwkv_chunked(r, k, v, w, u, chunk=32)
    b = ops.rwkv_chunked(r, k, v, w, u, chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

# ---------------------------------------------------------------------------
# v2: the fused fork--execute epoch kernel
# ---------------------------------------------------------------------------

# (family, fork_estimator, cu_model) covering every traced mechanism shape:
# pcstall, accpc, stall/lead/crit-style, crisp, accreac
EPOCH_FAMS = [("pc", False, None), ("pc", True, None),
              ("reactive", False, "stall"), ("reactive", False, "crisp"),
              ("reactive", True, None)]


def _epoch_case(family, CU, WF, *, seed=0, NF=10, T=3, E=16, tid=None,
                fork_estimator=False, cu_model=None, P=48):
    """Build one full operand set for ``epoch_fused`` from a real generated
    program plus randomized carry state. Returns (positional args, kwargs)."""
    rng = np.random.default_rng(seed)
    prog = make_program("kern", "mixed", seed % 17, P=P)
    sim = SimConfig(n_cu=CU, n_wf=WF)
    ax = sim.axes()
    F = PWR.freqs_ghz(ax.power, NF)
    pos = jnp.asarray(rng.uniform(0, P * 4, (CU, WF)).astype(np.float32))
    eps = SIM._epoch_context(prog, pos, prog.n_blocks, sim.seed).eps
    args = (prog.i0_rate, prog.sens_rate, jnp.transpose(prog.cum3), pos, F,
            eps, F[jnp.asarray(rng.integers(0, NF, CU))],
            jnp.asarray(rng.uniform(0, 5, CU).astype(np.float32)),
            jnp.float32(3.0))
    kw = dict(p_blocks=prog.n_blocks, epoch_us=ax.epoch_us, sigma=ax.sigma,
              cap_per_ghz=ax.cap_per_ghz, membw=ax.membw, obj=ax.obj,
              lat_us=PWR.transition_latency_us(ax.epoch_us, ax.power),
              power=ax.power, family=family,
              fork_estimator=fork_estimator, cu_model=cu_model)
    if family == "pc":
        kw.update(
            table=PRED.PCTable(
                jnp.asarray(rng.uniform(0, 6, (T, E)).astype(np.float32)),
                jnp.asarray(rng.uniform(0, 4, (T, E)).astype(np.float32)),
                jnp.asarray((rng.uniform(size=(T, E)) > 0.5)
                            .astype(np.float32))),
            tid=jnp.asarray(tid if tid is not None else np.arange(CU) % T,
                            jnp.int32),
            wf_i0=jnp.asarray(rng.uniform(0, 6, (CU, WF))
                              .astype(np.float32)),
            wf_sens=jnp.asarray(rng.uniform(0, 4, (CU, WF))
                                .astype(np.float32)))
    else:
        kw.update(react_i0=jnp.asarray(rng.uniform(0, 200, CU)
                                       .astype(np.float32)),
                  react_sens=jnp.asarray(rng.uniform(0, 100, CU)
                                         .astype(np.float32)))
    return args, kw


def _flat(out):
    leaves, _ = jax.tree_util.tree_flatten(out)
    return [np.asarray(x) for x in leaves]


@pytest.mark.parametrize("CU,WF,NF", [(4, 8, 10), (5, 7, 6), (3, 9, 4)])
@pytest.mark.parametrize("family,fork_est,model", EPOCH_FAMS)
def test_epoch_fused_via_pallas_matches_direct(CU, WF, NF, family,
                                               fork_est, model):
    """The pallas_call(interpret) engine and the direct-eval engine run the
    same kernel body: discrete outputs identical, floats at ulp level (the
    ref-simulation wrapper changes XLA fusion contexts, so bitwise equality
    is not a contract) — across odd shapes, odd ladders and every mechanism
    family."""
    args, kw = _epoch_case(family, CU, WF, NF=NF, seed=CU * NF + 1,
                           fork_estimator=fork_est, cu_model=model)
    a = KEF.epoch_fused(*args, **kw)
    b = KEF.epoch_fused(*args, **kw, via_pallas=True)
    for x, y in zip(_flat(a), _flat(b)):
        if np.issubdtype(x.dtype, np.integer):
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(x, y, rtol=3e-6, atol=3e-5)


@pytest.mark.parametrize("family,fork_est,model", EPOCH_FAMS)
def test_epoch_fused_invariants(family, fork_est, model):
    """Physical invariants of one fused epoch: waves only move forward,
    selected ladder index in range, telemetry finite and non-negative."""
    args, kw = _epoch_case(family, 6, 5, seed=11,
                           fork_estimator=fork_est, cu_model=model)
    out = KEF.epoch_fused(*args, **kw)
    pos0 = np.asarray(args[3])
    assert np.all(np.asarray(out.pos) >= pos0 - 1e-4)
    NF = args[4].shape[0]
    fidx = np.asarray(out.fidx)
    assert np.all((fidx >= 0) & (fidx < NF))
    assert np.all(np.asarray(out.work) >= 0)
    assert np.all(np.asarray(out.energy) > 0)
    assert np.all(np.isfinite(_flat(out)[0]))
    for leaf in _flat(out):
        assert np.all(np.isfinite(leaf))
    if family == "pc":
        assert np.all(np.asarray(out.table.count)
                      >= np.asarray(kw["table"].count))
        hr = float(out.hit_rate[0])
        assert 0.0 <= hr <= 1.0


def test_epoch_fused_noncontiguous_tid_permutation_invariance():
    """Relabeling table ids (permuting tid and the table rows consistently)
    must leave every CU-level output unchanged and permute the updated
    table rows the same way — i.e. the kernel honors arbitrary
    non-contiguous CU->table maps."""
    T = 3
    perm = np.array([2, 0, 1])
    inv = np.argsort(perm)
    tid_a = np.array([0, 2, 1, 0, 1, 2])
    args, kw_a = _epoch_case("pc", 6, 5, T=T, tid=tid_a, seed=5)
    kw_b = dict(kw_a)
    kw_b["tid"] = jnp.asarray(perm[tid_a], jnp.int32)
    tbl = kw_a["table"]
    kw_b["table"] = PRED.PCTable(tbl.i0[inv], tbl.sens[inv], tbl.count[inv])
    a = KEF.epoch_fused(*args, **kw_a)
    b = KEF.epoch_fused(*args, **kw_b)
    for field in ("pos", "wf_i0", "wf_sens", "f_sel", "e_acc", "work",
                  "energy", "err", "fidx", "true_sens", "hit_rate"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)
    for f in ("i0", "sens", "count"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.table, f)),
            np.asarray(getattr(b.table, f))[perm], err_msg=f)


def test_epoch_fused_out_of_range_tid_drops_updates():
    """Out-of-range table ids clamp on lookup and contribute nothing on
    update (predictors.table_update scatter-drop semantics)."""
    T, CU, WF = 3, 6, 5
    tid = np.array([0, 1, 2, T, T + 4, 1])      # two CUs map nowhere
    args, kw = _epoch_case("pc", CU, WF, T=T, tid=tid, seed=9)
    out = KEF.epoch_fused(*args, **kw)
    added = float(np.asarray(out.table.count).sum()
                  - np.asarray(kw["table"].count).sum())
    n_in_range = int((tid < T).sum())
    assert added == pytest.approx(n_in_range * WF)


@pytest.mark.parametrize("n_cu,n_wf", [(8, 10), (5, 7)])
def test_epoch_fused_exact_mode_matches_jnp_scan(monkeypatch, n_cu, n_wf):
    """With the lean reassociations disabled (exact reference op order) the
    v2 scan path reproduces the jnp path per-epoch, including odd
    CU/WF shapes."""
    monkeypatch.setattr(KEF, "epoch_fused",
                        functools.partial(KEF.epoch_fused, lean=False))
    jax.clear_caches()   # drop any lean-mode trace of the same signature
    try:
        prog = get_workload("comd")
        sim = SimConfig(n_cu=n_cu, n_wf=n_wf, n_epochs=40)
        for mech in ("pcstall", "accpc", "stall", "accreac"):
            a = run_sim(prog, sim, mech)
            b = run_sim(prog, dataclasses.replace(sim, use_pallas="v2"),
                        mech)
            for k in a:
                np.testing.assert_allclose(b[k], a[k], rtol=1e-5, atol=1e-5,
                                           err_msg=f"{mech}/{k}")
    finally:
        jax.clear_caches()  # don't leak exact-mode traces to other tests


# ---------------------------------------------------------------------------
# v2 fork mode: the traced-mechanism-id kernel serving the sweep layer
# ---------------------------------------------------------------------------

# do NOT fold these into EPOCH_FAMS above — test_property draws family
# indices against that list's layout
FORK_SPECS = [s for s in MECH.fork_specs() if s.is_traced]


def _fork_case(CU, WF, *, seed=0, NF=10, T=3, E=16, P=48):
    """Operands for a ``family='fork'`` call: the pc case's args plus the
    reactive state group and the registry-derived id statics (sans the
    per-spec shape kwargs, which fork mode resolves from the traced id)."""
    args, kw = _epoch_case("pc", CU, WF, seed=seed, NF=NF, T=T, E=E, P=P)
    rng = np.random.default_rng(seed + 77)
    kw.update(
        family="fork",
        react_i0=jnp.asarray(rng.uniform(0, 200, CU).astype(np.float32)),
        react_sens=jnp.asarray(rng.uniform(0, 100, CU).astype(np.float32)),
        react_models=tuple(s.cu_model for s in SIM._REACT_SPECS
                           if not s.fork_estimator),
        pc_ids=SIM._PC_IDS, id_ctr_pc=SIM._ID_CTR_PC)
    del kw["fork_estimator"], kw["cu_model"]
    return args, kw


@pytest.mark.parametrize("spec", FORK_SPECS, ids=lambda s: s.name)
def test_epoch_fused_fork_mode_matches_specialized(spec):
    """For every traced id, the fork-mode kernel must reproduce the
    specialized-family kernel run on identical carry state: the id-gated
    selects change WHICH state group advances, never the math. Discrete
    outputs exactly; floats at fusion-reassociation tolerance. The
    non-selected state group must pass through at carry values."""
    args, kw = _fork_case(8, 10, seed=31)
    out_f = KEF.epoch_fused(*args, **kw, mech=jnp.int32(spec.traced_id))
    skw = dict(kw)
    for k in ("react_models", "pc_ids", "id_ctr_pc"):
        del skw[k]
    skw.update(family=spec.family, fork_estimator=spec.fork_estimator,
               cu_model=spec.cu_model)
    if spec.family == "reactive":
        for k in ("table", "tid", "wf_i0", "wf_sens"):
            del skw[k]
    else:
        for k in ("react_i0", "react_sens"):
            del skw[k]
    out_s = KEF.epoch_fused(*args, **skw)
    np.testing.assert_array_equal(np.asarray(out_f.fidx),
                                  np.asarray(out_s.fidx))
    for field in ("pos", "f_sel", "e_acc", "t_acc", "work", "energy",
                  "err", "true_sens"):
        np.testing.assert_allclose(np.asarray(getattr(out_f, field)),
                                   np.asarray(getattr(out_s, field)),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{spec.name}/{field}")
    if spec.family == "pc":
        for f in ("i0", "sens", "count"):
            np.testing.assert_allclose(
                np.asarray(getattr(out_f.table, f)),
                np.asarray(getattr(out_s.table, f)),
                rtol=1e-5, atol=1e-5, err_msg=f"{spec.name}/table.{f}")
        for field in ("wf_i0", "wf_sens"):
            np.testing.assert_allclose(np.asarray(getattr(out_f, field)),
                                       np.asarray(getattr(out_s, field)),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{spec.name}/{field}")
        np.testing.assert_allclose(np.asarray(out_f.hit_rate),
                                   np.asarray(out_s.hit_rate),
                                   rtol=1e-6, atol=1e-6)
        # the reactive group is dead for a pc id: exact carry passthrough
        np.testing.assert_array_equal(np.asarray(out_f.react_i0),
                                      np.asarray(kw["react_i0"]))
        np.testing.assert_array_equal(np.asarray(out_f.react_sens),
                                      np.asarray(kw["react_sens"]))
    else:
        for field in ("react_i0", "react_sens"):
            np.testing.assert_allclose(np.asarray(getattr(out_f, field)),
                                       np.asarray(getattr(out_s, field)),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{spec.name}/{field}")
        # the table group is dead for a reactive id: exact passthrough
        for f in ("i0", "sens", "count"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out_f.table, f)),
                np.asarray(getattr(kw["table"], f)), err_msg=f)
        np.testing.assert_array_equal(np.asarray(out_f.wf_i0),
                                      np.asarray(kw["wf_i0"]))
        np.testing.assert_array_equal(np.asarray(out_f.wf_sens),
                                      np.asarray(kw["wf_sens"]))


@pytest.mark.parametrize("CU,WF,block_cu,cpd", [
    (8, 6, 4, 1), (8, 6, 2, 1), (16, 5, 4, 1), (8, 6, 4, 2),
])
def test_epoch_fused_fork_blocked_matches_unblocked(CU, WF, block_cu, cpd):
    """The blocked (CU,)-grid kernel pair (forced through
    pallas_call(interpret) on CPU via ``via_pallas``) agrees with the
    monolithic fork body: select is block-local and exact (fidx/f_sel
    equal), floats within the cross-block-reassociation + fully-lean
    tolerance; and without ``via_pallas`` the ``block_cu`` request is
    inert on the interpret engine (bitwise the monolithic body)."""
    args, kw = _fork_case(CU, WF, seed=CU + block_cu + cpd)
    kw["cus_per_domain"] = cpd
    # one reactive counter id, the fork-accurate reactive, both pc ids
    ids = (0, SIM._N_REACT - 1) + SIM._PC_IDS
    for mech_id in ids:
        m = jnp.int32(mech_id)
        a = KEF.epoch_fused(*args, **kw, mech=m)
        b = KEF.epoch_fused(*args, **kw, mech=m, block_cu=block_cu,
                            via_pallas=True)
        np.testing.assert_array_equal(np.asarray(b.fidx),
                                      np.asarray(a.fidx),
                                      err_msg=f"mech={mech_id}")
        np.testing.assert_array_equal(np.asarray(b.f_sel),
                                      np.asarray(a.f_sel),
                                      err_msg=f"mech={mech_id}")
        for x, y in zip(_flat(a), _flat(b)):
            if np.issubdtype(x.dtype, np.integer):
                np.testing.assert_array_equal(x, y)
            else:
                np.testing.assert_allclose(
                    y, x, rtol=2e-4, atol=2e-4,
                    err_msg=f"mech={mech_id}")
        c = KEF.epoch_fused(*args, **kw, mech=m, block_cu=block_cu)
        for x, y in zip(_flat(a), _flat(c)):
            np.testing.assert_array_equal(x, y)


def test_epoch_fused_lean_close_to_exact_single_epoch():
    """One epoch of lean math vs exact math: same ladder choice and
    continuous outputs within float-reassociation tolerance (the chaotic
    divergence of full scans comes from iterating near-ties, not from any
    single-epoch error)."""
    for fam, fork_est, model in EPOCH_FAMS:
        args, kw = _epoch_case(fam, 8, 10, seed=21,
                               fork_estimator=fork_est, cu_model=model)
        a = KEF.epoch_fused(*args, **kw, lean=False)
        b = KEF.epoch_fused(*args, **kw)
        np.testing.assert_array_equal(np.asarray(a.fidx),
                                      np.asarray(b.fidx))
        for field in ("pos", "work", "energy", "e_acc"):
            np.testing.assert_allclose(np.asarray(getattr(a, field)),
                                       np.asarray(getattr(b, field)),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{fam}/{field}")
