"""Tests for the learned-predictor subsystem (repro.learn) and its
supporting contracts: the shared deterministic stream/split/export
machinery in data.pipeline, dataset determinism (same seed -> bitwise
npz), the e2e tiny-train smoke, the ParamHook value-keyed hook contract
(swapping same-shape weights must NOT retrace the fork family; equal
weights must not retrace anything), learned-spec registration through
the audited grid path (dedup soundness, run_grid vs per-point dispatch,
DISPATCH_ROWS accounting, fork-compile bound), and the deadline-aware
objective lowering round-trip."""
import numpy as np
import pytest

from repro.core import mechanisms as MECH
from repro.core import sweep as SW
from repro.core.mechanisms import ParamHook
from repro.core.simulate import SimConfig, objective_weights, run_sim
from repro.core.sweep import run_grid, run_suite
from repro.core.workloads import get_workload
from repro.data import pipeline as PIPE
from repro.learn import dataset as LDS
from repro.learn import mechanism as LMECH
from repro.learn import models as LM
from repro.learn import train as LTR

WORKLOADS = ("comd", "xsbench")
TINY = LDS.DatasetConfig(workloads=WORKLOADS, seeds=(0,), epoch_us=(1.0,),
                         n_cu=8, n_epochs=64, warmup=8, val_frac=0.5)


@pytest.fixture(scope="module")
def progs():
    return {w: get_workload(w) for w in WORKLOADS}


@pytest.fixture(scope="module")
def tiny_data():
    return LDS.generate_dataset(TINY)


def _init_params(kind="linear", seed=0):
    """Deterministic untrained weights — dispatch tests don't need a fit."""
    return LM.INIT[kind](seed)


# ---------------------------------------------------------------------------
# data.pipeline: shared stream/split/export machinery
# ---------------------------------------------------------------------------


def test_stream_rng_counter_based():
    a = PIPE.stream_rng(7, 3).integers(0, 1 << 30, size=8)
    b = PIPE.stream_rng(7, 3).integers(0, 1 << 30, size=8)
    np.testing.assert_array_equal(a, b)
    c = PIPE.stream_rng(7, 4).integers(0, 1 << 30, size=8)
    assert not np.array_equal(a, c)


def test_dvfs_request_stream_uses_shared_stream():
    """Trace replay and training draw from the same counter machinery:
    request i is a pure function of (seed, i)."""
    r1 = list(PIPE.dvfs_request_stream(3, seed=5))
    r2 = list(PIPE.dvfs_request_stream(3, seed=5))
    for (p1, a1, t1), (p2, a2, t2) in zip(r1, r2):
        assert p1.name == p2.name and a1 == a2 and t1 == t2


def test_train_val_split_deterministic_partition():
    tr, va = PIPE.train_val_split(20, val_frac=0.25, seed=3)
    tr2, va2 = PIPE.train_val_split(20, val_frac=0.25, seed=3)
    np.testing.assert_array_equal(tr, tr2)
    np.testing.assert_array_equal(va, va2)
    assert len(va) == 5 and len(tr) == 15
    assert not set(tr) & set(va)
    assert sorted([*tr, *va]) == list(range(20))
    # a different seed moves the boundary; sizes are invariant
    tr3, va3 = PIPE.train_val_split(20, val_frac=0.25, seed=4)
    assert len(va3) == 5 and set(va3) != set(va)


def test_train_val_split_edges():
    tr, va = PIPE.train_val_split(2, val_frac=0.1, seed=0)
    assert len(va) == 1 and len(tr) == 1      # at least one of each
    tr, va = PIPE.train_val_split(5, val_frac=0.0, seed=0)
    assert len(va) == 0 and len(tr) == 5
    with pytest.raises(ValueError):
        PIPE.train_val_split(5, val_frac=1.0, seed=0)


def test_export_npz_roundtrip_and_meta(tmp_path):
    arrays = {"b": np.arange(6).reshape(2, 3), "a": np.ones(4, np.float32)}
    meta = {"k": [1, 2], "name": "x"}
    p = PIPE.export_npz(tmp_path / "d.npz", arrays, meta)
    got, got_meta = PIPE.load_npz(p)
    assert got_meta == meta
    for k, v in arrays.items():
        np.testing.assert_array_equal(got[k], v)


# ---------------------------------------------------------------------------
# dataset: determinism + schema
# ---------------------------------------------------------------------------


def test_dataset_determinism_bitwise(tmp_path, tiny_data):
    """Same DatasetConfig -> bitwise-identical npz artifact."""
    data1, meta1 = tiny_data
    data2, meta2 = LDS.generate_dataset(TINY)
    LDS.save_dataset(tmp_path / "a.npz", data1, meta1)
    LDS.save_dataset(tmp_path / "b.npz", data2, meta2)
    a = (tmp_path / "a.npz").read_bytes()
    b = (tmp_path / "b.npz").read_bytes()
    assert a == b


def test_dataset_schema_and_split(tiny_data):
    data, meta = tiny_data
    n = data["x"].shape[0]
    n_runs = len(meta["runs"])
    assert data["x"].shape == (n, LM.N_FEATURES)
    assert data["y"].shape == (n, LM.N_TARGETS)
    assert data["fidx"].shape == (n,)
    assert data["fidx"].min() >= 0
    assert data["fidx"].max() < len(meta["freqs_ghz"])
    # two behavior-policy trajectories (oracle + pcstall) per run
    expected = n_runs * 2 * (TINY.n_epochs - TINY.warmup) * TINY.n_cu
    assert n == expected
    assert data["policy"].shape == (n,)
    assert set(np.unique(data["policy"])) == {0, 1}
    assert (data["policy"] == 0).sum() == (data["policy"] == 1).sum()
    for k in ("x", "y", "t_us"):
        assert np.isfinite(data[k]).all(), k
    # by-run split: every run lands in exactly one side, and both policy
    # trajectories of a run land on the same side (no leakage)
    tr_mask, va_mask = LDS.split_masks(data)
    assert (tr_mask ^ va_mask).all()
    assert n_runs == len(TINY.workloads) * len(TINY.seeds) * \
        len(TINY.epoch_us)


def test_dataset_labels_match_select_mirror(tiny_data):
    """The offline objective mirror reproduces the oracle's own choices
    from the exact per-epoch (i0, sens) targets on most oracle-trajectory
    rows — the mirror and the labels speak the same objective. The
    pcstall-trajectory labels ARE the mirror by construction, so there
    they must agree exactly."""
    data, meta = tiny_data
    pbar = data["x"][:, list(meta["feature_names"]).index("pbar")]
    f = LDS.select_fidx(data["y"][:, 0], data["y"][:, 1], pbar,
                        data["t_us"], meta)
    orc = data["policy"] == 0
    agree = float(np.mean(f[orc] == data["fidx"][orc]))
    assert agree > 0.5, agree
    np.testing.assert_array_equal(f[~orc], data["fidx"][~orc])


# ---------------------------------------------------------------------------
# training: e2e smoke
# ---------------------------------------------------------------------------


def test_tiny_train_loss_decreases(tiny_data):
    """50 AdamW steps on 2 workloads: the deterministic probe loss (the
    jitter-free training objective on a fixed batch) strictly decreases
    and the frozen artifact is raw-space (folded normalization)."""
    data, meta = tiny_data
    params, curves = LTR.fit(data, meta, kind="linear", steps=50, seed=0)
    probe = curves["probe"]
    assert probe[-1] < probe[0], probe
    assert np.mean(probe[-3:]) < np.mean(probe[:3])
    assert len(curves["loss"]) == 50
    assert set(params) == {"w", "b"}
    # folded weights reproduce normalized-space inference on raw inputs
    x = data["x"][:64]
    mu_x, sd_x = curves["norm"]["mu_x"], curves["norm"]["sd_x"]
    mu_y, sd_y = curves["norm"]["mu_y"], curves["norm"]["sd_y"]
    unfolded = LM.fold_norm(params, np.zeros_like(mu_x),
                            np.ones_like(sd_x), np.zeros_like(mu_y),
                            np.ones_like(sd_y))
    np.testing.assert_allclose(
        np.asarray(LM.apply_model(unfolded, x)),
        np.asarray(LM.apply_model(params, x)), rtol=1e-5, atol=1e-5)


def test_fit_deterministic(tiny_data):
    data, meta = tiny_data
    p1, c1 = LTR.fit(data, meta, kind="linear", steps=20, seed=0)
    p2, c2 = LTR.fit(data, meta, kind="linear", steps=20, seed=0)
    assert c1["loss"] == c2["loss"]
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_weights_artifact_roundtrip(tmp_path):
    params = _init_params("mlp")
    p = LTR.save_weights(tmp_path / "w.npz", params,
                         extra_meta={"steps": 7})
    got, meta = LTR.load_weights(p)
    assert meta["kind"] == "mlp" and meta["steps"] == 7
    for k in params:
        np.testing.assert_array_equal(got[k], params[k])


def test_fold_norm_linear_exact():
    rng = np.random.default_rng(0)
    params = {"w": rng.standard_normal((LM.N_FEATURES, 2)).astype(np.float32),
              "b": rng.standard_normal(2).astype(np.float32)}
    mu_x = rng.standard_normal(LM.N_FEATURES).astype(np.float32)
    sd_x = rng.uniform(0.5, 2.0, LM.N_FEATURES).astype(np.float32)
    mu_y = rng.standard_normal(2).astype(np.float32)
    sd_y = rng.uniform(0.5, 2.0, 2).astype(np.float32)
    x = rng.standard_normal((32, LM.N_FEATURES)).astype(np.float32)
    folded = LM.fold_norm(params, mu_x, sd_x, mu_y, sd_y)
    want = np.asarray(LM.linear_apply(params, (x - mu_x) / sd_x)) \
        * sd_y + mu_y
    np.testing.assert_allclose(np.asarray(LM.linear_apply(folded, x)),
                               want, rtol=1e-4, atol=1e-4)


def test_fold_norm_mlp_exact():
    rng = np.random.default_rng(1)
    params = LM.init_mlp(1, hidden=8)
    params = {k: rng.standard_normal(v.shape).astype(np.float32)
              for k, v in params.items()}
    mu_x = rng.standard_normal(LM.N_FEATURES).astype(np.float32)
    sd_x = rng.uniform(0.5, 2.0, LM.N_FEATURES).astype(np.float32)
    mu_y = rng.standard_normal(2).astype(np.float32)
    sd_y = rng.uniform(0.5, 2.0, 2).astype(np.float32)
    x = rng.standard_normal((32, LM.N_FEATURES)).astype(np.float32)
    folded = LM.fold_norm(params, mu_x, sd_x, mu_y, sd_y)
    want = np.asarray(LM.mlp_apply(params, (x - mu_x) / sd_x)) * sd_y + mu_y
    np.testing.assert_allclose(np.asarray(LM.mlp_apply(folded, x)),
                               want, rtol=1e-4, atol=1e-4)


def test_predict_targets_residual_trust_region():
    """The deployed prediction is the reactive digest plus a correction
    clamped to TRUST_RADIUS x |react|: zero weights reproduce the react
    columns exactly, and arbitrarily large weights cannot leave the
    trust envelope (the closed-loop stability guarantee)."""
    rng = np.random.default_rng(2)
    x = np.abs(rng.standard_normal((64, LM.N_FEATURES))
               ).astype(np.float32) * 100.0
    react = x[:, list(LM.REACT_COLS)]
    zero = {"w": np.zeros((LM.N_FEATURES, 2), np.float32),
            "b": np.zeros((2,), np.float32)}
    np.testing.assert_array_equal(
        np.asarray(LM.predict_targets(zero, x)), react)
    huge = {"w": np.full((LM.N_FEATURES, 2), 1e6, np.float32),
            "b": np.full((2,), 1e6, np.float32)}
    out = np.asarray(LM.predict_targets(huge, x))
    lim = LM.TRUST_RADIUS * np.abs(react)
    assert (out <= react + lim + 1e-4).all()
    assert (out >= react - lim - 1e-4).all()


# ---------------------------------------------------------------------------
# ParamHook: the parameterized-hook contract
# ---------------------------------------------------------------------------


def test_param_hook_value_equality():
    pa = _init_params(seed=0)
    h1 = ParamHook(LMECH.learned_predict, pa)
    h2 = ParamHook(LMECH.learned_predict,
                   {k: v.copy() for k, v in pa.items()})  # fresh arrays
    assert h1 == h2 and hash(h1) == hash(h2)
    pb = {k: v + 1.0 for k, v in pa.items()}              # same shapes
    h3 = ParamHook(LMECH.learned_predict, pb)
    assert h1 != h3
    # a different hook fn with equal params is a different hook
    h4 = ParamHook(LMECH.learned_update, pa)
    assert h1 != h4
    # specs built around value-equal hooks are value-equal (cache keys)
    s1 = LMECH.make_learned_spec("learned_eq", pa)
    s2 = LMECH.make_learned_spec("learned_eq",
                                 {k: v.copy() for k, v in pa.items()})
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1 != LMECH.make_learned_spec("learned_eq", pb)


def test_param_hook_weight_swap_does_not_retrace_fork_family(progs):
    """THE regression the contract exists for: swapping hook weights of
    identical shape/dtype must not retrace the shared fork family
    (TRACE_COUNTS["grid_forks"] delta 0 after the first compile), and
    re-creating a spec around equal-valued weights must retrace nothing
    at all."""
    sim = SimConfig(n_cu=8, n_wf=8, n_epochs=24, entries=16,
                    offset_blocks=8)
    pa = _init_params(seed=0)
    pb = {k: v + 0.25 for k, v in pa.items()}   # same shape/dtype
    sa = LMECH.make_learned_spec("learned_swap", pa)
    run_grid(progs, sim, [{}], ("crisp", sa))   # warm fork family + A

    SW.reset_counters()
    sb = LMECH.make_learned_spec("learned_swap", pb)
    run_grid(progs, sim, [{}], ("crisp", sb))
    assert SW.TRACE_COUNTS.get("grid_forks", 0) == 0, \
        dict(SW.TRACE_COUNTS)
    # the new weights get their OWN specialized compile (never a stale
    # aliased executable)
    assert SW.TRACE_COUNTS.get("grid_learned_swap", 0) == 1, \
        dict(SW.TRACE_COUNTS)

    SW.reset_counters()
    sa2 = LMECH.make_learned_spec(
        "learned_swap", {k: v.copy() for k, v in pa.items()})
    got = run_grid(progs, sim, [{}], ("crisp", sa2))
    assert sum(SW.TRACE_COUNTS.values()) == 0, dict(SW.TRACE_COUNTS)
    # and the cached executable serves the equal-valued spec bitwise
    want = run_grid(progs, sim, [{}], ("crisp", sa))
    for w in WORKLOADS:
        for ch in ("work", "energy", "fidx"):
            np.testing.assert_array_equal(
                got[()][w]["learned_swap"][ch],
                want[()][w]["learned_swap"][ch], err_msg=f"{w}/{ch}")


# ---------------------------------------------------------------------------
# learned mechanisms through the audited grid path
# ---------------------------------------------------------------------------


def test_learned_specs_register_audited():
    """Registration runs the axis-liveness audit; the learned hooks
    genuinely consume every declared axis."""
    for name, kind in (("learned_lin", "linear"), ("learned_mlp", "mlp")):
        spec = LMECH.register_learned(name, _init_params(kind))
        try:
            assert spec.exec_axes == MECH.SIM_AXES_FIELDS
            assert MECH.get(name) == spec
            from repro.analysis.deps import (axis_liveness,
                                             require_dedup_sound)
            res = axis_liveness(spec)
            assert not res.under_declared, res
            assert not res.over_declared, res
            require_dedup_sound(spec)
        finally:
            MECH.unregister(name)


def test_learned_grid_matches_per_point_and_dedup(progs):
    """Grid rows equal per-point dispatch; DISPATCH_ROWS shows the pc
    spec scanning once per grid point (every axis live) while a static
    collapses the objective axis — and the whole mixed sweep stays within
    the fork-family compile bound."""
    sim = SimConfig(n_cu=8, n_wf=8, n_epochs=24, entries=16,
                    offset_blocks=8)
    spec = LMECH.make_learned_spec("learned_t", _init_params(seed=3))
    objs = ["ed2p", "deadline05"]
    SW.reset_counters()
    grid = run_grid(progs, sim, {"objective": objs},
                    ("static17", "crisp", "pcstall", "oracle", spec))
    fork_family = sum(SW.TRACE_COUNTS.get(k, 0)
                      for k in ("grid_forks", "grid_oracle"))
    assert fork_family <= 2, dict(SW.TRACE_COUNTS)
    W, G = len(progs), len(objs)
    assert SW.DISPATCH_ROWS["grid_learned_t"] == W * G
    assert SW.DISPATCH_ROWS["grid_static17"] == W          # obj collapsed
    assert SW.DISPATCH_ROWS["grid_forks"] == W * G * 2     # crisp+pcstall
    import jax
    for obj in objs:
        import dataclasses
        want = run_suite(progs, dataclasses.replace(sim, objective=obj),
                         (spec,))
        for w in WORKLOADS:
            for ch in ("work", "energy", "fidx", "hit_rate"):
                got = grid[(obj,)][w]["learned_t"][ch]
                ref = want[w]["learned_t"][ch]
                if jax.local_device_count() == 1:
                    np.testing.assert_array_equal(
                        got, ref, err_msg=f"{obj}/{w}/{ch}")
                else:
                    np.testing.assert_allclose(
                        got, ref, rtol=1e-5, atol=1e-5,
                        err_msg=f"{obj}/{w}/{ch}")


def test_learned_run_sim_trace_schema(progs):
    """run_sim accepts the spec by value and emits the pc-family trace
    schema including hit telemetry; the learned controller actually
    exercises the ladder rather than pinning one frequency."""
    sim = SimConfig(n_cu=8, n_wf=8, n_epochs=48, entries=16,
                    offset_blocks=8)
    tr = run_sim(progs["comd"], sim,
                 LMECH.make_learned_spec("learned_s", _init_params(seed=1)))
    assert {"work", "energy", "err", "fidx", "true_sens",
            "hit_rate"} <= set(tr)
    assert tr["fidx"].shape == (sim.n_epochs, sim.n_cu)
    assert np.isfinite(tr["work"]).all()


# ---------------------------------------------------------------------------
# deadline-aware objective lowering
# ---------------------------------------------------------------------------


def test_deadline_objective_lowering_roundtrip():
    np.testing.assert_array_equal(objective_weights("deadline05"),
                                  np.asarray([1.0, 0.0, 0.95], np.float32))
    np.testing.assert_array_equal(objective_weights("deadline10"),
                                  np.asarray([1.0, 0.0, 0.90], np.float32))
    # distinct from perfcap by exactly the Pbar Lagrangian term
    np.testing.assert_array_equal(
        objective_weights("deadline05") - objective_weights("perfcap05"),
        np.asarray([1.0, 0.0, 0.0], np.float32))
    for bad in ("deadline", "deadline5", "deadline123", "deadlineXY"):
        with pytest.raises(ValueError):
            objective_weights(bad)


def test_deadline_objective_sweeps_like_any_axis(progs):
    """deadline<pct> rides the existing objective axis: live for
    selecting mechanisms (distinct traces), collapsed for statics."""
    sim = SimConfig(n_cu=8, n_wf=8, n_epochs=32, entries=16,
                    offset_blocks=8)
    SW.reset_counters()
    grid = run_grid(progs, sim, {"objective": ["ed2p", "deadline05"]},
                    ("static17", "crisp"))
    assert SW.DISPATCH_ROWS["grid_static17"] == len(progs)
    tr_a = grid[("ed2p",)]["comd"]["crisp"]
    tr_b = grid[("deadline05",)]["comd"]["crisp"]
    assert not np.array_equal(tr_a["fidx"], tr_b["fidx"])
    # statics are broadcast bitwise across the collapsed axis
    np.testing.assert_array_equal(
        grid[("ed2p",)]["comd"]["static17"]["energy"],
        grid[("deadline05",)]["comd"]["static17"]["energy"])
    # the deadline constraint binds: sustained rate stays near the cap
    f_dead = grid[("deadline05",)]["comd"]["crisp"]["fidx"]
    assert f_dead.mean() > grid[("ed2p",)]["comd"]["crisp"]["fidx"].mean()
