"""Tests for ``repro.analysis``: the jaxpr axis-liveness auditor (every
builtin's declared ``exec_axes`` must be *derived*, not trusted; deliberate
under-/over-declared mutants must be caught at registration AND at
``run_grid(dedup=True)`` dispatch) and the trace-hazard linter (each rule
REPRO001–006 fires on a minimal synthetic snippet, stays quiet on the
clean variant, and honors waivers). Plus the wiring: the verified
mechanism table and the machine-readable report the CI lane consumes."""
import textwrap

import pytest

from repro.analysis import deps, lint, report
from repro.analysis.deps import (AxisLivenessError, DeadAxisWarning,
                                 axis_liveness, verify_spec_axes)
from repro.core import mechanisms as MECH
from repro.core import simulate as SIM
from repro.core.mechanisms import MechanismSpec

CTRL = ("epoch_us", "sigma", "cap_per_ghz", "membw", "obj", "n_ep", "power")


def _sneaky_predict(carry, ctx, st, ax):
    # reads table_ema without declaring it — the dedup-unsound direction
    i0 = carry.react_i0 * (1.0 + 0.1 * ax.table_ema)
    return SIM.predict_instr(i0, carry.react_sens, st, ax)


def _honest_predict(carry, ctx, st, ax):
    return SIM.predict_instr(carry.react_i0, carry.react_sens, st, ax)


# ---------------------------------------------------------------------------
# Axis-liveness auditor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", MECH.BUILTIN_NAMES)
def test_builtin_declarations_are_derived_exactly(name):
    """THE acceptance criterion: for every builtin, the auditor's derived
    liveness equals the hand-declared exec_axes exactly — no waivers, no
    under- or over-declaration anywhere in the paper set."""
    res = axis_liveness(name)
    assert res.waiver is None
    assert res.exact, (
        f"{name}: declared={res.declared} derived={res.derived} "
        f"under={res.under_declared} over={res.over_declared}")
    # and the union really is per-output: every channel's axes are a
    # subset of the derived set and at least one channel is non-empty
    assert res.per_output
    for ch, axes in res.per_output:
        assert set(axes) <= set(res.derived), (ch, axes)


def test_under_declared_mutant_rejected_at_registration():
    """A custom hook smuggling in an undeclared axis must be rejected by
    the default registration-time audit, with the culprit axis named, and
    must NOT end up in the registry."""
    spec = MechanismSpec("mut_under", "reactive", CTRL,
                         predict=_sneaky_predict)
    with pytest.raises(AxisLivenessError, match="table_ema"):
        MECH.register(spec)
    assert "mut_under" not in MECH.names()
    # the diagnostic names at least one output channel it flows into
    res = axis_liveness(spec)
    assert res.under_declared == ("table_ema",)
    assert not res.sound
    assert any("table_ema" in axes for _, axes in res.per_output)


def test_under_declared_mutant_refused_by_run_grid(progs_one):
    """Even a spec that dodged the registration audit (verify_axes=False)
    is refused by run_grid(dedup=True) BEFORE any deduped dispatch —
    and runs fine with dedup=False, where no broadcast can lie."""
    from repro.core.sweep import run_grid
    spec = MechanismSpec("mut_under2", "reactive", CTRL,
                         predict=_sneaky_predict)
    MECH.register(spec, verify_axes=False)
    try:
        cfg = SIM.SimConfig(n_cu=4, n_wf=4, n_epochs=8)
        grid = {"table_ema": [0.3, 0.5]}
        with pytest.raises(AxisLivenessError, match="table_ema"):
            run_grid(progs_one, cfg, grid, ("mut_under2",))
        res = run_grid(progs_one, cfg, grid, ("mut_under2",), dedup=False)
        assert len(res) == 2
    finally:
        MECH.unregister("mut_under2")


def test_over_declared_mutant_warns_naming_dead_axis():
    """Over-declaration is correct-but-wasteful: registration succeeds
    with a DeadAxisWarning naming the dead axis."""
    spec = MechanismSpec("mut_over", "reactive", CTRL + ("table_ema",),
                         predict=_honest_predict)
    with pytest.warns(DeadAxisWarning, match="table_ema"):
        MECH.register(spec)
    try:
        assert "mut_over" in MECH.names()
        res = axis_liveness(spec)
        assert res.over_declared == ("table_ema",)
        assert res.sound  # over-declaration never breaks the dedup
    finally:
        MECH.unregister("mut_over")


def test_waiver_downgrades_under_declaration():
    """A documented liveness_waiver turns the hard error into a warning
    carrying the waiver text (for auditor false positives only)."""
    spec = MechanismSpec("mut_waived", "reactive", CTRL,
                         predict=_sneaky_predict,
                         liveness_waiver="test: deliberate mutant")
    with pytest.warns(DeadAxisWarning, match="deliberate mutant"):
        res = verify_spec_axes(spec)
    assert res.under_declared == ("table_ema",)
    assert res.sound  # waived => dispatchable


def test_audit_registry_covers_all_builtins():
    results = deps.audit_registry()
    assert {r.name for r in results} >= set(MECH.BUILTIN_NAMES)
    assert all(r.sound for r in results)


def test_builtin_declarations_exact_under_v2_engine():
    """Dual-engine audit, v2 leg: under ``TINY_CONFIG_V2`` (the fused
    epoch kernel as scan body) every builtin's derived liveness still
    equals its declared exec_axes exactly — the kernel body must not
    smuggle axes the jnp body doesn't read (e.g. the packed scalar
    operand must not make ``table_ema`` live for table-free specs)."""
    for name in MECH.BUILTIN_NAMES:
        res = axis_liveness(name, deps.TINY_CONFIG_V2)
        assert res.waiver is None
        assert res.exact, (
            f"{name} under v2: declared={res.declared} "
            f"derived={res.derived} under={res.under_declared} "
            f"over={res.over_declared}")


def test_under_declared_spec_rejected_by_dual_audit_at_registration():
    """Registration runs the jnp audit AND (on the interpret engine,
    where the kernel body is a walkable jaxpr) the v2-config audit: a
    sneaky under-declared spec is rejected with the culprit axis named,
    and the v2-config derivation independently convicts the same axis."""
    spec = MechanismSpec("mut_under_v2", "reactive", CTRL,
                         predict=_sneaky_predict)
    with pytest.raises(AxisLivenessError, match="table_ema"):
        MECH.register(spec)
    assert "mut_under_v2" not in MECH.names()
    res2 = axis_liveness(spec, deps.TINY_CONFIG_V2)
    assert res2.under_declared == ("table_ema",)
    assert not res2.sound


def test_mechanism_table_has_verified_column():
    table = MECH.mechanism_table()
    assert "| verified |" in table
    # every builtin row is ✓ (exact) — the README table is evidence
    rows = [r for r in table.splitlines() if r.startswith("| `")]
    assert len(rows) >= len(MECH.BUILTIN_NAMES)
    for name in MECH.BUILTIN_NAMES:
        row = next(r for r in rows if f"`{name}`" in r)
        assert "✓" in row, row
    # the unverified variant still renders (no tracing)
    assert "| verified |" not in MECH.mechanism_table(verify=False)


@pytest.fixture(scope="module")
def progs_one():
    from repro.core.workloads import get_workload
    return {"comd": get_workload("comd", P=128)}


# ---------------------------------------------------------------------------
# Trace-hazard linter
# ---------------------------------------------------------------------------


def _rules(src):
    return sorted({f.rule for f in lint.lint_source(textwrap.dedent(src))
                   if not f.waived})


def test_repro001_host_sync_in_jitted_fn():
    src = """
    import jax, numpy as np
    @jax.jit
    def f(x):
        return float(x) + np.asarray(x).sum() + x.item()
    """
    assert _rules(src) == ["REPRO001"]
    # shape reads are static — exempt
    assert _rules("""
    import jax
    @jax.jit
    def f(x):
        return int(x.shape[0])
    """) == []


def test_repro002_python_branch_on_traced_value():
    src = """
    import jax, jax.numpy as jnp
    @jax.jit
    def f(x):
        if jnp.any(x > 0):
            return x
        return -x
    """
    assert _rules(src) == ["REPRO002"]
    # plain-python condition in untraced code: quiet
    assert _rules("""
    def g(n):
        if n > 0:
            return n
    """) == []


def test_repro003_numpy_in_traced_code():
    src = """
    import jax, numpy as np
    @jax.jit
    def f(x):
        return np.tanh(x)
    """
    assert _rules(src) == ["REPRO003"]
    # dtype constructors / constants are exempt
    assert _rules("""
    import jax, numpy as np
    @jax.jit
    def f(x):
        return x * np.float32(2.0) + np.pi
    """) == []


def test_repro004_jitted_scan_without_donation():
    src = """
    import jax
    from jax import lax
    @jax.jit
    def f(carry, xs):
        return lax.scan(lambda c, x: (c + x, c), carry, xs)
    """
    assert _rules(src) == ["REPRO004"]
    assert _rules("""
    import functools, jax
    from jax import lax
    @functools.partial(jax.jit, donate_argnums=(0,))
    def f(carry, xs):
        return lax.scan(lambda c, x: (c + x, c), carry, xs)
    """) == []


def test_repro005_dict_ordering_hazards():
    src = """
    import jax
    @jax.jit
    def f(x, keys):
        return {k: x for k in keys}
    """
    assert _rules(src) == ["REPRO005"]
    assert "REPRO005" in _rules("""
    import jax
    @jax.jit
    def f(x, names):
        return dict(zip(names, [x, x]))
    """)
    # literal keys are a static treedef: quiet
    assert _rules("""
    import jax
    @jax.jit
    def f(x):
        return {"a": x, "b": -x}
    """) == []


def test_repro006_unlocked_module_state():
    src = """
    COUNTS = {}
    def bump(k):
        COUNTS[k] = COUNTS.get(k, 0) + 1
    """
    assert _rules(src) == ["REPRO006"]
    # guarded by a lock: quiet
    assert _rules("""
    import threading
    COUNTS = {}
    _LOCK = threading.Lock()
    def bump(k):
        with _LOCK:
            COUNTS[k] = COUNTS.get(k, 0) + 1
    """) == []


def test_traced_context_propagates_through_local_calls():
    """A helper called from a jitted function is traced too (fixpoint
    propagation), even without its own decorator."""
    src = """
    import jax, numpy as np
    def helper(x):
        return np.tanh(x)
    @jax.jit
    def f(x):
        return helper(x)
    """
    assert _rules(src) == ["REPRO003"]


def test_scan_body_lambda_is_traced():
    src = """
    import numpy as np
    from jax import lax
    def run(xs):
        return lax.scan(lambda c, x: (c, np.log(x)), 0.0, xs)
    """
    assert _rules(src) == ["REPRO003"]


def test_waivers_line_and_file():
    line = """
    import jax
    @jax.jit
    def f(x):
        return float(x)  # repro: waive[REPRO001] test waiver
    """
    findings = lint.lint_source(textwrap.dedent(line))
    assert [f.rule for f in findings] == ["REPRO001"]
    assert findings[0].waived
    filewide = """
    # repro: waive-file[REPRO006] single-threaded module
    STATE = {}
    def bump(k):
        STATE[k] = 1
    """
    findings = lint.lint_source(textwrap.dedent(filewide))
    assert all(f.waived for f in findings)
    assert lint.violations(findings) == []


def test_lint_rules_table_is_complete():
    assert sorted(lint.RULES) == [f"REPRO00{i}" for i in range(1, 7)]


# ---------------------------------------------------------------------------
# Report / CI wiring
# ---------------------------------------------------------------------------


def test_report_schema_and_ok():
    rep = report.build_report()
    assert rep["schema"] == 1
    names = {r["name"] for r in rep["liveness"]["results"]}
    assert names >= set(MECH.BUILTIN_NAMES)
    assert rep["liveness"]["unsound"] == []
    # the shipped tree must be lint-clean modulo waivers — this IS the CI
    # gate, asserted here so tier-1 catches regressions before the lane
    assert rep["lint"]["violations"] == 0, rep["lint"]["findings"]
    assert rep["ok"]
    # JSON-serializable end to end
    assert "liveness" in report.to_json(rep)
    assert "OK" in report.render_text(rep)


def test_source_tree_has_no_unwaived_findings():
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    findings = lint.lint_paths([root / "src" / "repro"])
    assert lint.violations(findings) == [], \
        [f.format() for f in lint.violations(findings)]


def test_audit_never_perturbs_reference_numerics():
    """The auditor only abstract-evals: running it must not change the
    grid reference contract (byte-identity is asserted by test_grid's
    reference comparison; here we pin that the audit compiles nothing
    new into the sweep dispatch families)."""
    from repro.core import sweep as SW
    SW.reset_counters()
    deps.audit_registry()
    assert dict(SW.TRACE_COUNTS) == {}
    assert dict(SW.DISPATCH_ROWS) == {}
