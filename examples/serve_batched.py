"""Serve a reduced RWKV6 (attention-free: O(1)-state decode) with batched
requests: prefill + 48 decode steps, plus the DVFS phase report.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.configs import get_smoke_config
from repro.launch.serve import serve

cfg = get_smoke_config("rwkv6-3b")
rep = serve(cfg, batch=4, prompt_len=64, gen=48, dvfs=True)
print(f"prefill {rep['prefill_s']*1e3:.1f}ms, "
      f"decode {rep['decode_s_per_tok']*1e3:.2f}ms/tok")
print(f"dvfs energy {rep['dvfs']['energy_norm']:.3f}x static, "
      f"accuracy {rep['dvfs']['accuracy']:.3f}")
