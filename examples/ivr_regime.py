"""Sweep the IVR transition-latency regime — the paper's core hardware
premise — and print the ED2P crossover table.

"Predict; Do not React" argues that on-chip integrated voltage regulators
(IVRs) shrinking V/f transition latency from the us range into the ns
range (4ns dead time at 1us epochs, §5) are what make fine-grain DVFS
worth doing at all. With the power model split into a static
``PowerStatic`` and a traced ``PowerAxes``, that premise is a one-line
sweep: each hardware regime is a ``PowerConfig`` value on the ``power``
grid axis of ``run_grid``, and the whole ns->sub-us ladder of regimes
runs as one jit-cached executable family.

The table this prints shows the crossover: at the paper's 4ns regime the
predictive mechanism (PCSTALL) converts most of the oracle's headroom at
1us epochs; as the regulator slows toward legacy off-chip latencies, the
per-transition dead time eats the fine-grain gains until predictive DVFS
stops beating the static baseline entirely.

  PYTHONPATH=src python examples/ivr_regime.py
"""
import dataclasses

import numpy as np

from repro.core import mechanisms as MECH
from repro.core import power as PWR
from repro.core.simulate import SimConfig
from repro.core.sweep import run_grid, suite_metrics
from repro.core.workloads import get_workload

WLS = ("comd", "hacc", "xsbench")
MECHS = ("static17", "crisp", "pcstall", "oracle")

# label = transition latency at the 1us operating point; the slope
# ``lat_per_us`` scales the paper's schedule (4ns @ 1us, capped at 400ns)
# from the on-chip IVR regime up two decades toward off-chip regulators
# (keep lat_cap_us below the epoch: dead time beyond the epoch has no
# physical reading)
REGIMES = {
    "  4ns": PWR.PowerConfig(),                    # paper: on-chip IVR
    " 13ns": PWR.PowerConfig(lat_per_us=1.3e-2),
    " 40ns": PWR.PowerConfig(lat_per_us=4e-2),
    "130ns": PWR.PowerConfig(lat_per_us=1.3e-1, lat_cap_us=0.9),
    "400ns": PWR.PowerConfig(lat_per_us=4e-1, lat_cap_us=0.9),
}

progs = {w: get_workload(w) for w in WLS}
cfg = SimConfig(n_epochs=500)  # 1us epochs: the fine-grain operating point

# ONE dispatch family for the whole regime ladder: power is a traced axis
grid = run_grid(progs, cfg, {"power": list(REGIMES.values())}, MECHS)

print(f"ED^2P vs static 1.7 GHz (geomean over {', '.join(WLS)}; "
      "1us epochs)")
header = "  ".join(f"{MECH.get(m).label.split()[0]:>8s}" for m in MECHS[1:])
print(f"{'regime':>6s}  {header}")
rows = {}
for label, pw in REGIMES.items():
    sim = dataclasses.replace(cfg, power=pw)
    r = suite_metrics(None, sim, MECHS, n=2, traces=grid[(pw,)])
    rows[label] = {m: float(np.exp(np.mean([np.log(r[w][m]["ednp_norm"])
                                            for w in WLS]))) for m in MECHS}
    print(f"{label:>6s}  " + "  ".join(f"{rows[label][m]:8.3f}"
                                       for m in MECHS[1:]))

crossed = [label for label, r in rows.items() if r["pcstall"] >= 1.0]
if crossed:
    print(f"\ncrossover: predictive fine-grain DVFS stops beating the "
          f"static baseline at the {crossed[0].strip()} regime — "
          "the ns-scale IVR premise is load-bearing")
else:
    print("\nno crossover in this range: predictive DVFS still pays at "
          "the slowest regime swept (try epoch_us < 1 or slower slopes)")
