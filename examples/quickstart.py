"""Quickstart: the paper's mechanism in 30 lines.

Runs PCSTALL vs reactive CRISP vs ORACLE on one GPU workload and prints
prediction accuracy + normalized ED2P (paper Figs 14/15).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.simulate import SimConfig, run_workload
from repro.core.workloads import get_workload

sim = SimConfig(n_epochs=600)            # 64-CU GPU, 1us epochs, ED2P
prog = get_workload("comd")              # Molecular Dynamics proxy app

results = run_workload(prog, sim,
                       mechanisms=("static17", "crisp", "pcstall", "oracle"))
print(f"{'mechanism':10s} {'accuracy':>9s} {'ED2P vs 1.7GHz':>15s}")
for mech, r in results.items():
    acc = "-" if mech.startswith("static") else f"{r['accuracy']:.3f}"
    print(f"{mech:10s} {acc:>9s} {r['ednp_norm']:>15.3f}")
