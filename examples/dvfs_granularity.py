"""Scalability study (paper Fig 18b): PCSTALL at 1/4/16-CU V/f domain
granularity on a phased workload.

  PYTHONPATH=src python examples/dvfs_granularity.py
"""
from repro.core.simulate import SimConfig, run_workload
from repro.core.workloads import get_workload

prog = get_workload("hacc")
for g in (1, 4, 16):
    sim = SimConfig(n_epochs=500, cus_per_domain=g, cus_per_table=g)
    r = run_workload(prog, sim, mechanisms=("static17", "pcstall", "oracle"))
    print(f"{g:2d}-CU domains: pcstall ED2P={r['pcstall']['ednp_norm']:.3f} "
          f"oracle={r['oracle']['ednp_norm']:.3f}")
