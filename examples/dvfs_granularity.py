"""Scalability study (paper Fig 18b + 17): PCSTALL across V/f-domain
granularities, epoch durations and objectives on a phased workload.

The V/f-domain size reshapes (CU -> domain) arrays, so it is a static shape
axis looped in Python; everything else — epoch duration and objective —
is a traced ``run_grid`` axis, so each domain size runs its whole
(epoch_us x objective) grid as one device-sharded executable family (the
same family a single ``run_suite`` point would use — there is only one
dispatch path). The static-1.7 baseline is deduplicated across the two
objectives: it scans once per epoch duration, not once per grid point.

  PYTHONPATH=src python examples/dvfs_granularity.py
"""
import dataclasses

from repro.core import mechanisms as MECH
from repro.core.simulate import SimConfig
from repro.core.sweep import run_grid, suite_metrics
from repro.core.workloads import get_workload

prog = get_workload("hacc")
GRID = {"epoch_us": [1.0, 10.0], "objective": ["ed2p", "edp"]}
# resolved through the MechanismSpec registry: the baseline and the two
# predictors, addressable by name or spec everywhere below
MECHS = tuple(MECH.get(m) for m in ("static17", "pcstall", "oracle"))
BASELINE = MECHS[0]

for g in (1, 4, 16):
    cfg = SimConfig(n_epochs=500, cus_per_domain=g, cus_per_table=g)
    grid = run_grid([prog], cfg, GRID, MECHS)
    for (T, obj), traces in grid.items():
        n = 2 if obj == "ed2p" else 1
        r = suite_metrics(None, dataclasses.replace(cfg, epoch_us=T,
                                                    objective=obj),
                          MECHS, n=n, traces=traces,
                          baseline=BASELINE)[prog.name]
        print(f"{g:2d}-CU domains {T:5.1f}us {obj:4s}: "
              f"pcstall ED^{n}P={r['pcstall']['ednp_norm']:.3f} "
              f"oracle={r['oracle']['ednp_norm']:.3f}")
