"""Register a NEW DVFS mechanism without touching the engine or the sweep
layer — the extension path the MechanismSpec registry exists for.

The mechanism here is a DSO-style fused static+dynamic estimator (after
Wang et al., "DSO: A GPU Energy Efficiency Optimizer by Fusing Dynamic and
Static Information", arXiv:2407.13096): next-epoch instructions are
predicted from a blend of

  * a *static code feature* — the per-CU linear (i0, sens) model the
    program text implies at the wavefronts' current PC blocks (available
    to every predictor through the epoch context), and
  * the *dynamic* CU-level reactive state digested from hardware counters
    (the CRISP estimator feeding the standard reactive carry).

Registration is pure data: a ``MechanismSpec`` with ``predict``/``update``
hooks. The spec's ``exec_axes`` declare it table-free, so the sweep layer
automatically dedups it across ``table_ema``-only grid axes, it gets its
own jit-cached specialized executable (exactly like oracle), and every
consumer — ``run_grid``, ``suite_metrics``, the DVFS manager — accepts it
by name or spec with no engine edits.

The ``exec_axes`` declaration is *checked*, not trusted: ``register``
audits custom specs by default (``repro.analysis.deps`` abstract-evals
the spec's scan — hooks included — and derives its true axis liveness
from the jaxpr), so a hook that quietly read ``ax.table_ema`` without
declaring it would be rejected right here with an AxisLivenessError
instead of silently broadcasting wrong numbers through the grid dedup.

  PYTHONPATH=src python examples/custom_mechanism.py
"""
from repro.core import estimators as EST
from repro.core import mechanisms as MECH
from repro.core import simulate as SIM
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import SimConfig
from repro.core.sweep import run_grid, suite_metrics
from repro.core.workloads import get_workload

ALPHA = 0.5  # static-code-feature weight of the blend


def dso_predict(carry, ctx, st, ax):
    """Blend static code features with the dynamic reactive state and
    lower to the capacity-clipped (CU, 10) prediction."""
    # static part: the program's local block rates under the wavefronts
    # right now, aggregated to CU level like the reactive estimators
    i0_code = ctx.i0_l.sum(-1)
    s_code = ctx.s_l.sum(-1)
    i0 = ALPHA * i0_code + (1.0 - ALPHA) * carry.react_i0
    sens = ALPHA * s_code + (1.0 - ALPHA) * carry.react_sens
    return SIM.predict_instr(i0, sens, st, ax)


def dso_update(counters, f_sel, I_f, carry, ctx, st, ax):
    """Digest this epoch's counters with the CRISP model into the dynamic
    half of the blend (rate units: instr/us, instr/us/GHz)."""
    i0_cu, s_cu = EST.cu_estimate(counters, f_sel, "crisp")
    return i0_cu / ax.epoch_us, s_cu / ax.epoch_us


DSO = MECH.register(MechanismSpec(
    "dso", "reactive",
    # "power" is mandatory for every spec: the V/f ladder and the energy
    # accounting make the traced IVR regime live in all mechanisms
    exec_axes=("epoch_us", "sigma", "cap_per_ghz", "membw", "obj", "n_ep",
               "power"),
    label="DSO (static+dynamic blend)",
    predict=dso_predict, update=dso_update))


if __name__ == "__main__":
    progs = {w: get_workload(w) for w in ("comd", "hacc", "xsbench")}
    cfg = SimConfig(n_epochs=400)
    MECHS = ("static17", "crisp", "dso", "pcstall")
    grid = run_grid(progs, cfg, {"objective": ["ed2p", "edp"]}, MECHS)
    for obj, n in (("ed2p", 2), ("edp", 1)):
        import dataclasses
        r = suite_metrics(None, dataclasses.replace(cfg, objective=obj),
                          MECHS, n=n, traces=grid[(obj,)])
        for wl in progs:
            row = "  ".join(
                f"{MECH.get(m).label}={r[wl][m]['ednp_norm']:.3f}"
                for m in MECHS if m != "static17")
            print(f"{obj:4s} {wl:8s} ED^{n}P vs static1.7: {row}")
