"""Train a ~small LM end-to-end on CPU (reduced llama3-family config):
data pipeline -> AdamW -> remat'd train_step -> checkpoint/resume ->
PCSTALL DVFS energy report.

  PYTHONPATH=src python examples/train_lm.py
"""
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch.train import train

cfg = get_smoke_config("llama3-405b")
shape = ShapeConfig("demo", seq_len=128, global_batch=8, kind="train")
tc = TrainConfig(lr=3e-3, total_steps=60, warmup_steps=6, microbatches=2,
                 checkpoint_dir="/tmp/repro_example_ckpt", checkpoint_every=25)
state, losses = train(cfg, tc, shape, steps=60, dvfs=True)
assert losses[-1] < losses[0], "loss should decrease"
print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
