"""PaliGemma 3B [arXiv:2407.07726]: SigLIP vision stub + gemma backbone (MQA).

The SigLIP tower is a stub: input_specs supplies 256 precomputed patch
embeddings which are prepended to the text embeddings; loss masks image slots.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    rope_theta=10_000.0, attn_kind="full", frontend="vision", n_patches=256,
)
