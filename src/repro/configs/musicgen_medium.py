"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Backbone only; the EnCodec frontend is a stub (input_specs supplies token ids
over the 2048-entry codebook directly).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64,
    rope_theta=10_000.0, attn_kind="full", frontend="audio",
)
