"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``. Configs are plain frozen dataclasses so they hash, print, and
serialize cleanly, and never touch jax at import time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    # d_ff of each routed expert (shared experts use the same unless overridden)
    expert_d_ff: int = 0
    shared_d_ff: int = 0
    router_jitter: float = 0.0
    # load-balancing aux loss weight
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16
    conv_width: int = 4
    expand: int = 1  # inner expansion for mamba blocks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention flavour: 'full' | 'swa' (sliding window) | 'none'
    attn_kind: str = "full"
    window: int = 2048  # for swa
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    n_patches: int = 256  # vision frontend: number of patch embeddings
    dtype: str = "bfloat16"
    # remat policy: 'none' | 'full' | 'dots'
    remat: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 64  # attention-free archs (rwkv heads)

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context (O(<seq^2) prefill, O(1)/O(w) cache)?"""
        return self.attn_kind in ("none", "swa") or self.family == "ssm"

    @property
    def n_params(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        per_layer = 0
        if self.attn_kind != "none" and self.n_heads:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            per_layer += q + kv + o
        if self.family in ("ssm",) or (self.ssm is not None and self.family == "hybrid"):
            # rwkv/mamba mixing params approx: 4 d^2-ish
            per_layer += 4 * d * d
        if self.moe is not None:
            e = self.moe
            per_layer += e.num_experts * 3 * d * e.expert_d_ff
            per_layer += e.num_shared * 3 * d * (e.shared_d_ff or e.expert_d_ff)
            per_layer += d * e.num_experts  # router
        else:
            per_layer += 3 * d * self.d_ff  # swiglu
        per_layer += 2 * d  # norms
        return emb + head + L * per_layer

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE uses top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params
        e = self.moe
        d, L = self.d_model, self.n_layers
        routed_all = e.num_experts * 3 * d * e.expert_d_ff
        routed_active = e.top_k * 3 * d * e.expert_d_ff
        return self.n_params - L * (routed_all - routed_active)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shapes applicable to an arch. long_500k only for sub-quadratic archs
    (skip documented in DESIGN.md §Arch-applicability)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Train / runtime config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient accumulation
    grad_compression: str = "none"  # 'none' | 'bf16' | 'int8_ef'
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # single pod (data, model); multi-pod (pod, data, model)
    pod: int = 2
    data: int = 16
    model: int = 16

    @property
    def shape(self):
        return (self.pod, self.data, self.model) if self.multi_pod else (self.data, self.model)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=0,
        d_ff=128,
        vocab=512,
        head_dim=16 if cfg.n_heads else 16,
    )
    if cfg.n_heads:
        # preserve the GQA ratio shape (kv <= q heads)
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        kw["n_kv_heads"] = max(1, kw["n_heads"] // min(ratio, kw["n_heads"]))
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            expert_d_ff=64,
            shared_d_ff=64,
        )
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(state_size=8)
    if cfg.frontend == "vision":
        kw["n_patches"] = 4
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
