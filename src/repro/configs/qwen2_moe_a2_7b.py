"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4 +
4 shared experts, expert d_ff=1408."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128,
    rope_theta=1_000_000.0, attn_kind="full",
    moe=MoEConfig(num_experts=60, top_k=4, num_shared=4,
                  expert_d_ff=1408, shared_d_ff=1408),
)
