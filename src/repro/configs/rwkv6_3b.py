"""RWKV-6 (Finch) 3B [arXiv:2404.05892]: attention-free, data-dependent decay
linear attention; 40 heads of 64 channels."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536, head_dim=64,
    attn_kind="none", ssm=SSMConfig(state_size=64),
)
