"""Architecture registry. ``get_config(name)`` returns the full published config;
``get_smoke_config(name)`` a reduced same-family config for CPU tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    TrainConfig,
    reduced,
    shapes_for,
)

ARCH_IDS: List[str] = [
    "llama3-405b",
    "glm4-9b",
    "granite-20b",
    "phi3-mini-3.8b",
    "musicgen-medium",
    "hymba-1.5b",
    "paligemma-3b",
    "rwkv6-3b",
    "qwen2-moe-a2.7b",
    "granite-moe-1b-a400m",
    # the paper's own evaluation platform expressed as a config (GPU sim side)
]

_MODULES: Dict[str, str] = {
    "llama3-405b": "llama3_405b",
    "glm4-9b": "glm4_9b",
    "granite-20b": "granite_20b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1_5b",
    "paligemma-3b": "paligemma_3b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_IDS}
