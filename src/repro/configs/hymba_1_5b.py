"""Hymba 1.5B [arXiv:2411.13676]: hybrid — parallel attention + mamba heads,
sliding-window attention (global attention only on a few layers; we model SWA
throughout which is what makes long_500k feasible), ssm_state=16."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    rope_theta=10_000.0, attn_kind="swa", window=1024,
    ssm=SSMConfig(state_size=16),
)
