"""Granite-3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
32 experts top-8, expert d_ff=512."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    rope_theta=10_000.0, attn_kind="full",
    moe=MoEConfig(num_experts=32, top_k=8, num_shared=0,
                  expert_d_ff=512, shared_d_ff=512),
)
