"""Roofline report: aggregate dry-run JSONs into the EXPERIMENTS.md table.

Per (arch x shape x mesh) cell:
  compute/memory/collective terms (seconds, per device, trip-count-aware),
  dominant term, MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill)
  / 2*N_active*B (decode), useful-compute ratio, and an automatic
  what-would-move-it note.

  PYTHONPATH=src python -m repro.roofline.report [--mesh sp|mp] [--csv]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import get_config, shapes_for
from repro.roofline.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    N = cfg.n_active_params
    if shape.kind == "train":
        tok = shape.global_batch * shape.seq_len
        total = 6.0 * N * tok
    elif shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len
        total = 2.0 * N * tok
    else:  # decode: one token per sequence
        total = 2.0 * N * shape.global_batch
    return total / n_devices


def load_cell(arch: str, shape: str, mesh: str, tag: str = "") -> Optional[Dict]:
    name = f"{arch}_{shape}_{mesh}" + (f"_{tag}" if tag else "")
    f = DRYRUN / f"{name}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def advice(dom: str, row: Dict) -> str:
    if dom == "t_collective_s":
        return "overlap/compress collectives; move TP reduce to rs+ag; shard seq"
    if dom == "t_memory_s":
        if row.get("useful_ratio", 1) < 0.5:
            return "cut remat recompute + causal-block attention (skip masked tiles)"
        return "raise arithmetic intensity: fuse ops, bf16 activations, larger tiles"
    return "compute-bound: good; next win is MXU-aligned tiling"


def build_rows(mesh: str = "sp", tag: str = "") -> List[Dict]:
    rows = []
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in shapes_for(cfg):
            rec = load_cell(arch, s.name, mesh, tag)
            if rec is None:
                continue
            ta = rec["tripaware"]
            n_dev = rec["n_devices"]
            t_c = ta["flops"] / PEAK_FLOPS
            t_m = ta["hbm_bytes"] / HBM_BW
            t_x = ta["collective_bytes"] / ICI_BW
            bound = max(t_c, t_m, t_x)
            dom = {t_c: "t_compute_s", t_m: "t_memory_s",
                   t_x: "t_collective_s"}[bound]
            mf = model_flops_per_device(arch, s.name, n_dev)
            row = {
                "arch": arch, "shape": s.name, "mesh": mesh,
                "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
                "dominant": dom,
                "roofline_fraction": t_c / bound if bound else 0.0,
                "model_flops_dev": mf,
                "hlo_flops_dev": ta["flops"],
                "useful_ratio": mf / ta["flops"] if ta["flops"] else 0.0,
                "hbm_gb_dev": rec["memory"].get("temp_size_in_bytes", 0) / 1e9,
            }
            row["advice"] = advice(dom, row)
            rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "roofline frac | useful FLOP ratio | temp GB/dev | next move |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant'].replace('t_', '').replace('_s', '')} | "
            f"{r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} | "
            f"{r['hbm_gb_dev']:.1f} | {r['advice']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="sp", choices=("sp", "mp"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = build_rows(args.mesh, args.tag)
    if args.csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    else:
        print(to_markdown(rows))


if __name__ == "__main__":
    main()
