"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, so a
126-layer scan under-reports FLOPs by 126x. XLA:CPU however annotates every
while with ``backend_config={"known_trip_count":{"n":...}}``. We parse the
module into computations, propagate loop multipliers (ENTRY=1, while body
multiplier = parent multiplier x trip count, nested loops compose), and then
account per top-level op:

  * dot FLOPs        : 2 x |output| x |contracting dims|  (x multiplier)
  * HBM bytes        : output bytes + operand bytes of top-level ops
                       (fusion bodies are internal; not traversed)
  * collective bytes : result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

This gives per-device totals (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE_TOK = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPCODE = re.compile(r"^(?:\(|[a-z0-9\[\],\s\{\}/\*]*?)\s*([a-z][a-z0-9\-]*)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_OPERAND = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    out = []
    for dt, dims in _SHAPE_TOK.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Op:
    name: str
    rhs: str  # everything after '='
    opcode: str
    result_bytes: int


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # var -> type str
    is_entry: bool = False


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur = None
    hdr_re = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
    for line in txt.splitlines():
        if cur is None:
            s = line.strip()
            if s.startswith("HloModule"):
                continue
            m = hdr_re.match(s) if s.endswith("{") else None
            if m and (s.startswith(("ENTRY", "%")) or "->" in s):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(s)
        if not m:
            continue
        var, rhs = m.group(1), m.group(2)
        type_prefix, opcode = _split_type_op(rhs)
        cur.shapes[var] = type_prefix
        cur.ops.append(Op(var, rhs, opcode, _shape_bytes(type_prefix)))
    return comps


def _split_type_op(rhs: str) -> Tuple[str, str]:
    """Split '<result type> <opcode>(...)' — result type may be a tuple."""
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_prefix, rest = rhs[:end], rhs[end:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, ""
        type_prefix, rest = rhs[:sp], rhs[sp + 1:].strip()
    m = re.match(r"([a-z][a-z0-9\-]*)\(", rest)
    return type_prefix, (m.group(1) if m else "")


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    mult: Dict[str, float] = {}
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # iterate to fixpoint (nesting depth is small)
    for _ in range(16):
        changed = False
        for comp in comps.values():
            if comp.name not in mult:
                continue
            base = mult[comp.name]
            for op in comp.ops:
                if op.opcode == "while":
                    trip = _TRIP.search(op.rhs)
                    n = int(trip.group(1)) if trip else 1
                    for pat, scale in ((_BODY, n), (_COND, n + 1)):
                        t = pat.search(op.rhs)
                        if t:
                            tgt = t.group(1)
                            val = base * scale
                            if mult.get(tgt, 0) < val:
                                mult[tgt] = val
                                changed = True
                elif op.opcode in ("conditional", "call", "async-start"):
                    for t in _CALLS.finditer(op.rhs):
                        tgt = t.group(1)
                        if mult.get(tgt, 0) < base:
                            mult[tgt] = base
                            changed = True
        if not changed:
            break
    return mult


def _dot_flops(op: Op, shapes: Dict[str, str]) -> int:
    out_dims = _shape_dims(op.rhs[:op.rhs.find("dot(")])
    out_elems = 1
    for d in (out_dims[0] if out_dims else []):
        out_elems *= d
    # operands: first two %vars inside dot(...)
    inner = op.rhs[op.rhs.find("dot(") + 4:]
    ops_names = _OPERAND.findall(inner[:inner.find(")")])
    lhs_contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
    contract_elems = 1
    if ops_names and lhs_contract and ops_names[0] in shapes:
        lhs_dims = _shape_dims(shapes[ops_names[0]])
        if lhs_dims:
            for idx in lhs_contract.group(1).split(","):
                if idx and int(idx) < len(lhs_dims[0]):
                    contract_elems *= lhs_dims[0][int(idx)]
    return 2 * out_elems * contract_elems


def analyze(txt: str) -> Dict[str, float]:
    comps = parse_module(txt)
    mult = _multipliers(comps)
    flops = 0.0
    hbm_bytes = 0.0
    coll: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = _CALLS.search(op.rhs)
                if m:
                    fusion_bodies.add(m.group(1))
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0 or comp.name in fusion_bodies:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, comp.shapes)
            base_op = op.opcode.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVES and not op.opcode.endswith("-done"):
                coll[base_op] += m * op.result_bytes
            # HBM traffic: top-level op outputs (operand reads roughly mirror
            # producer outputs; counting outputs once avoids double-counting)
            if op.opcode == "dynamic-update-slice":
                # XLA updates in place inside while loops: real traffic is the
                # update slice (operand 1), not the whole buffer.
                ops_names = _OPERAND.findall(op.rhs.split("(", 1)[1])
                upd = ops_names[1] if len(ops_names) > 1 else None
                hbm_bytes += m * _shape_bytes(comp.shapes.get(upd, ""))
            elif op.opcode in ("fusion", "dot", "copy", "dynamic-slice",
                               "gather", "scatter",
                               "transpose", "reshape", "broadcast", "reduce",
                               "convert", "sort", "iota", "concatenate",
                               "slice", "pad", "select-and-scatter") or \
                    base_op in COLLECTIVES:
                hbm_bytes += m * op.result_bytes
    coll_total = sum(coll.values())
    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": coll_total,
            **{f"coll_{k}": v for k, v in coll.items()}}


# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def roofline_terms(analysis: Dict[str, float]) -> Dict[str, float]:
    t_compute = analysis["flops"] / PEAK_FLOPS
    t_memory = analysis["hbm_bytes"] / HBM_BW
    t_coll = analysis["collective_bytes"] / ICI_BW
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    terms["dominant"] = dom
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
