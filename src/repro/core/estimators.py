"""Frequency-sensitivity estimation models (paper §2.3, Table III).

All estimators consume only *hardware-counter-visible* quantities produced
by the epoch execution model:

  committed  (CU,WF)  instructions committed this epoch
  core_frac  (CU,WF)  fraction of epoch NOT stalled at s_waitcnt
  issue_q    (CU,WF)  issued/demanded ratio (scheduling-contention squeeze)
  lead_frac  (CU,WF)  fraction of stall time attributable to leading loads

Ground truth: committed = (i0 + sens*f)*T, core_frac = sens*f/(i0+sens*f),
so the *wavefront-level* STALL estimator
    sens = committed * core_frac / f
is exact modulo contention/bandwidth coupling — the paper's observation that
simple models work at wavefront granularity (§4.2). CU-level models aggregate
counters before estimating and therefore mis-handle heterogeneous wavefront
mixes (Jensen-gap); the four baselines differ in how faithfully they account
asynchronous time, reproducing the paper's ordering
STALL < LEAD < CRIT < CRISP.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

CU_MODELS = ("stall", "lead", "crit", "crisp")


def wf_stall_estimate(counters: Dict[str, jnp.ndarray], f: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-wavefront STALL model, age/contention-normalized (paper §4.4).
    Returns (i0_wf, sens_wf), shapes (CU,WF). f is (CU,) executed GHz."""
    c = counters["committed"]
    # hardware exposes ONE scheduling-contention counter per CU, not per WF:
    # the age normalization uses the CU-mean issue ratio (paper: estimates are
    # "normalized depending on the relative age"), which is approximate.
    q_cu = jnp.maximum(counters["issue_q"].mean(-1, keepdims=True), 0.05)
    fb = f[:, None]
    # stall time is measured in coarse ticks -> quantized core fraction
    cf = jnp.round(counters["core_frac"] * 16.0) / 16.0
    demand = c / q_cu
    sens = demand * cf / fb
    i0 = jnp.maximum(demand - sens * fb, 0.0)
    return i0, sens


def cu_estimate(counters: Dict[str, jnp.ndarray], f: jnp.ndarray, model: str
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CU-level estimators used by the reactive baselines. Returns
    (i0_cu, sens_cu), shapes (CU,)."""
    c = counters["committed"]          # (CU,WF)
    cf = counters["core_frac"]
    q = jnp.maximum(counters["issue_q"], 0.05)
    fb = f[:, None]
    I_cu = c.sum(-1)

    if model == "stall":
        # single-thread view: unweighted mean core fraction of the CU
        cf_cu = cf.mean(-1)
        sens = I_cu * cf_cu / f
    elif model == "lead":
        # leading-load accounting ~ committed-weighted core fraction
        cf_cu = (c * cf).sum(-1) / jnp.maximum(c.sum(-1), 1e-6)
        sens = I_cu * cf_cu / f
    elif model == "crit":
        # critical-path: committed-weighted + contention correction
        cf_cu = (c * cf).sum(-1) / jnp.maximum(c.sum(-1), 1e-6)
        sens = I_cu * cf_cu / (f * jnp.maximum(q.mean(-1), 0.05))
    elif model == "crisp":
        # per-WF core products summed at CU level (store stalls + overlap)
        sens = ((c / q) * cf).sum(-1) / f
    else:
        raise ValueError(model)
    i0 = jnp.maximum(I_cu - sens * f, 0.0)
    return i0, sens
