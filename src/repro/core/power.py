"""GPU V/f-domain power model (paper §5 "Power Model") — hardware as data.

P_total = (P_dyn + P_leak) / eta_ivr
  P_dyn  = C_eff * V^2 * f * A      (A = activity factor from committed work)
  P_leak = k_leak * V               (leakage ~ linear in V over the narrow
                                     IVR range; temperature held constant)
V(f) is linear over the evaluated 1.3-2.2 GHz range (paper §3.2 linearity).
Transition overhead: energy ~ C*dV^2 plus dead time = transition latency
(4ns @ 1us epochs ... 400ns @ 100us, paper §5).

The hardware regime is *sweepable*, split exactly like ``SimConfig``:

* :class:`PowerStatic` — the shape half: the ladder length ``n_freqs``
  (it sizes every (.., n_freqs) array in the engine). Hashable jit key,
  carried inside ``simulate.SimStatic``.
* :class:`PowerAxes` — the traced half: V/f endpoints, capacitance,
  leakage, IVR efficiency, transition energy and the transition-latency
  model, as a pytree of f32 scalars. Carried inside ``simulate.SimAxes``,
  so ``sweep.run_grid`` stacks whole IVR regimes along the grid axis like
  any other traced axis — the paper's core premise (IVR latency shrinking
  from the us to the ns range is what unlocks fine-grain DVFS) becomes a
  one-line sensitivity sweep (``benchmarks.paper_figs.fig_ivr_regime``,
  ``examples/ivr_regime.py``).
* :class:`PowerConfig` — the user-facing frozen point: both halves as
  Python scalars, with ``static_part()`` / ``axes()`` mirrors of
  ``SimConfig``'s. Hashable, so the sweep layer's exec-axes dedup can key
  equivalence classes on it directly. NOTE: every mechanism — including
  the static frequencies — is live in the power axes (the ladder, the
  energy accounting and the transition model all read them), so unlike
  ``objective``/``table_ema`` a swept power axis never collapses.

The transition-latency model replaces the old hardcoded
``min(4e-3 * epoch_us, 0.4)`` slope: latency(us) =
``min(lat_per_us * epoch_us, lat_cap_us)``. The defaults reproduce the
paper's schedule (4ns @ 1us, 40ns @ 10us, 400ns cap from 100us);
``lat_per_us`` 10x/100x higher models a slow (legacy, off-chip) IVR.

Every model function takes the power parameters explicitly and accepts a
``PowerConfig`` (Python floats — constants in a trace) or a ``PowerAxes``
(traced scalars — the sweep hot path) interchangeably; the default is the
paper's operating point, so pre-existing call sites are unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Union

import jax.numpy as jnp


@dataclass(frozen=True)
class PowerStatic:
    """Shape half of the power model: the V/f ladder length. Part of the
    engine's jit key (``SimStatic``) — it sizes the fork batch, the
    prediction arrays and the frequency-selection cost matrix."""
    n_freqs: int = 10

    def __post_init__(self):
        assert self.n_freqs >= 2, \
            f"a V/f ladder needs >= 2 states, got {self.n_freqs}"


class PowerAxes(NamedTuple):
    """Traced half of the power model: one IVR/hardware regime as a pytree
    of () f32 scalars, carried inside ``SimAxes`` so the sweep layer can
    stack regimes along a grid axis and vmap over them."""
    f_min: jnp.ndarray       # GHz, bottom of the V/f ladder
    f_max: jnp.ndarray       # GHz, top of the V/f ladder
    v_min: jnp.ndarray       # V at f_min
    v_max: jnp.ndarray       # V at f_max
    c_eff: jnp.ndarray       # effective capacitance per CU (arb. unit)
    k_leak: jnp.ndarray      # leakage coefficient (P_leak = k_leak * V)
    eta0: jnp.ndarray        # IVR efficiency at v_min
    eta_slope: jnp.ndarray   # efficiency droop towards v_max
    c_trans: jnp.ndarray     # transition energy per unit dV^2
    lat_per_us: jnp.ndarray  # transition latency slope (us per epoch-us)
    lat_cap_us: jnp.ndarray  # transition latency cap (us)


@dataclass(frozen=True)
class PowerConfig:
    v_min: float = 0.70       # V at f_min
    v_max: float = 1.00       # V at f_max
    f_min: float = 1.3
    f_max: float = 2.2
    c_eff: float = 1.0        # arbitrary capacitance unit per CU
    k_leak: float = 0.35      # leakage at V=1 equals ~20% of dyn at fmax
    eta0: float = 0.92        # IVR efficiency at v_min
    eta_slope: float = -0.05  # efficiency droop towards v_max
    c_trans: float = 0.005    # transition energy per unit dV^2
    lat_per_us: float = 4e-3  # paper §5: 4ns dead time per 1us of epoch
    lat_cap_us: float = 0.4   # ... capped at 400ns (the 100us point)
    n_freqs: int = 10         # ladder length (static: it sets shapes)

    def static_part(self) -> PowerStatic:
        """The hashable shape half (nested in ``SimStatic``)."""
        return PowerStatic(n_freqs=self.n_freqs)

    def axes(self) -> PowerAxes:
        """The traced regime point (nested in ``SimAxes``)."""
        return PowerAxes(*(jnp.float32(getattr(self, f))
                           for f in PowerAxes._fields))


# the paper's operating point — the default of every model function below
DEFAULT = PowerConfig()

# a PowerConfig (Python floats) and a PowerAxes (traced scalars) expose the
# same field names, so the model functions take either
PowerParams = Union[PowerConfig, PowerAxes]

FREQS_GHZ = jnp.linspace(1.3, 2.2, 10)  # default ladder: 10 states, 100 MHz
F_STATIC = 1.7  # normalization baseline (paper Figs 15/17)


def freqs_ghz(pw: PowerParams, n_freqs: Optional[int] = None) -> jnp.ndarray:
    """The V/f ladder: ``n_freqs`` states linearly spaced on
    [``pw.f_min``, ``pw.f_max``].

    ``n_freqs`` is the *static* ladder length (defaults to ``pw.n_freqs``
    when ``pw`` is a PowerConfig; a traced ``PowerAxes`` carries no shape,
    so pass ``SimStatic.power.n_freqs`` explicitly). Uses the same
    endpoint-blend formula ``jnp.linspace`` lowers to — ``lo*(1-t) + hi*t``
    with the exact endpoint concatenated — so inside a jitted trace the
    default-regime ladder is bitwise-identical to :data:`FREQS_GHZ`."""
    if n_freqs is None:
        n_freqs = pw.n_freqs  # PowerAxes has no n_freqs: pass it explicitly
    assert n_freqs >= 2, n_freqs
    lo = jnp.asarray(pw.f_min, jnp.float32)
    hi = jnp.asarray(pw.f_max, jnp.float32)
    t = jnp.arange(n_freqs - 1, dtype=jnp.float32) / jnp.float32(n_freqs - 1)
    return jnp.concatenate([lo * (1.0 - t) + hi * t, hi[None]])


def v_of_f(f, pw: PowerParams = DEFAULT):
    t = (f - pw.f_min) / (pw.f_max - pw.f_min)
    return pw.v_min + t * (pw.v_max - pw.v_min)


def ivr_eta(v, pw: PowerParams = DEFAULT):
    t = (v - pw.v_min) / (pw.v_max - pw.v_min)
    return pw.eta0 + pw.eta_slope * t


def power(f, activity, pw: PowerParams = DEFAULT):
    """Power of one V/f domain at frequency f (GHz) with activity in [0,1]."""
    v = v_of_f(f, pw)
    p_dyn = pw.c_eff * v * v * f * jnp.clip(activity, 0.05, 1.0)
    p_leak = pw.k_leak * v
    return (p_dyn + p_leak) / ivr_eta(v, pw)


def transition_energy(f_old, f_new, pw: PowerParams = DEFAULT):
    dv = v_of_f(f_new, pw) - v_of_f(f_old, pw)
    return pw.c_trans * dv * dv


def transition_latency_us(epoch_us, pw: PowerParams = DEFAULT):
    """V/f transition dead time: ``min(lat_per_us * epoch_us, lat_cap_us)``.

    The default regime reproduces the paper's §5 schedule (4ns @ 1us,
    40ns @ 10us, 200/400ns @ 50/100us epochs); the sweep path passes the
    traced latency model from ``SimAxes.power`` instead, making the IVR
    regime a grid axis. Accepts a Python float or a traced jnp scalar for
    ``epoch_us``. Keep ``lat_cap_us`` below the shortest epoch you sweep:
    a dead time exceeding the epoch has no physical reading."""
    return jnp.minimum(pw.lat_per_us * epoch_us, pw.lat_cap_us)
