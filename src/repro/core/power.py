"""GPU V/f-domain power model (paper §5 "Power Model").

P_total = (P_dyn + P_leak) / eta_ivr
  P_dyn  = C_eff * V^2 * f * A      (A = activity factor from committed work)
  P_leak = k_leak * V               (leakage ~ linear in V over the narrow
                                     IVR range; temperature held constant)
V(f) is linear over the evaluated 1.3-2.2 GHz range (paper §3.2 linearity).
Transition overhead: energy ~ C*dV^2 plus dead time = transition latency
(4ns @ 1us epochs ... 400ns @ 100us, paper §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

FREQS_GHZ = jnp.linspace(1.3, 2.2, 10)  # 10 V/f states, 100 MHz steps
F_STATIC = 1.7  # normalization baseline (paper Figs 15/17)


@dataclass(frozen=True)
class PowerConfig:
    v_min: float = 0.70       # V at 1.3 GHz
    v_max: float = 1.00       # V at 2.2 GHz
    f_min: float = 1.3
    f_max: float = 2.2
    c_eff: float = 1.0        # arbitrary capacitance unit per CU
    k_leak: float = 0.35      # leakage at V=1 equals ~20% of dyn at fmax
    eta0: float = 0.92        # IVR efficiency at v_min
    eta_slope: float = -0.05  # efficiency droop towards v_max
    c_trans: float = 0.005     # transition energy per unit dV^2


def v_of_f(f, pc: PowerConfig = PowerConfig()):
    t = (f - pc.f_min) / (pc.f_max - pc.f_min)
    return pc.v_min + t * (pc.v_max - pc.v_min)


def ivr_eta(v, pc: PowerConfig = PowerConfig()):
    t = (v - pc.v_min) / (pc.v_max - pc.v_min)
    return pc.eta0 + pc.eta_slope * t


def power(f, activity, pc: PowerConfig = PowerConfig()):
    """Power of one V/f domain at frequency f (GHz) with activity in [0,1]."""
    v = v_of_f(f, pc)
    p_dyn = pc.c_eff * v * v * f * jnp.clip(activity, 0.05, 1.0)
    p_leak = pc.k_leak * v
    return (p_dyn + p_leak) / ivr_eta(v, pc)


def transition_energy(f_old, f_new, pc: PowerConfig = PowerConfig()):
    dv = v_of_f(f_new, pc) - v_of_f(f_old, pc)
    return pc.c_trans * dv * dv


def transition_latency_us(epoch_us):
    """Paper §5: 4ns @ 1us, 40ns @ 10us, 200/400ns @ 50/100us epochs.

    Accepts a Python float or a traced jnp scalar (the sweep layer traces
    ``epoch_us`` as a grid axis)."""
    return jnp.minimum(4e-3 * epoch_us, 0.4)
