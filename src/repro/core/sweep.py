"""Batched sweep layer: one compiled executable per mechanism *family*
for the whole figure grid, instead of one trace per (workload, mechanism,
seed, grid-point) tuple.

The paper's headline figures (14/15/17/18) sweep ~10 mechanisms x ~10
workloads x epoch granularities x objectives through the fork--pre-execute
engine. Run serially that is hundreds of scan traces; this layer instead

  1. pads every ``Program`` to a common block count (``pad_program`` keeps
     the wrapped prefix-sum window semantics exact by rebuilding the doubled
     cumulative arrays at the *logical* length before padding, and threads
     the logical block count through the scan as a traced scalar);
  2. stacks the padded programs into one pytree and ``vmap``s the
     simulation scan across workloads and seeds (both traced: the noise
     hash takes the seed as a scalar operand);
  3. vmaps across mechanisms *within a family*: all fork--pre-execute
     mechanisms (``simulate.FORK_MECHS``) share a shape-identical carry and
     run as one executable indexed by a traced mechanism id, while the
     static-frequency mechanisms compile to their own (fork-free, ~10x
     cheaper) executable per frequency;
  4. (``run_grid``) stacks whole ``SimAxes`` grid points — epoch_us, sigma,
     capacity, bandwidth, EMA, lowered objective, logical epoch count —
     along a leading axis, cartesian-products them with the workloads, and
     shards the flattened (workload x grid-point) axis across local
     devices with ``shard_map`` (a 1-device mesh is the identity layout).
     Points with fewer logical epochs scan to the grid max and mask the
     tail, the same pad-and-mask move applied to programs.

A full Fig-15/17/18-style sweep over several epoch granularities and
objectives is therefore at most two fork-family executables (the traced-id
family plus oracle's specialized one) plus one per static frequency point;
repeated sweeps with the same ``SimStatic`` hit the jit cache and never
re-trace (``TRACE_COUNTS`` records compiles for tests/benchmarks).

Execution-model / caching contract: see ``repro.core.simulate``'s module
docstring. ``run_grid`` output is bitwise-equal to per-point ``run_suite``
(same traced-id family; vmap/shard_map preserve per-row reduction order —
tested by ``tests/test_grid.py``), and ``run_suite`` matches the
specialized per-mechanism ``run_sim`` traces to f32 exactness (tested to
1e-5 by ``tests/test_sweep.py``). Across *differently specialized*
executables (traced-id family vs a ``run_sim`` string-mech trace) the math
is identical at the jaxpr level but XLA may fuse f32 chains differently;
at epoch_us != 1 the resulting last-ulp differences can compound through
the closed control loop over hundreds of epochs, so cross-family
comparisons should use matching dispatch paths.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import simulate as SIM
from repro.core.simulate import (MECHANISMS, SimAxes, SimConfig, SimStatic,
                                 ednp, prediction_accuracy)
from repro.core.workloads import Program

_STATIC_MECHS = ("static13", "static17", "static22")
_PC_MECHS = ("pcstall", "accpc")


def _unpack_trace(arrs: Dict[str, jnp.ndarray], w: int, mech: str,
                  squeeze_seed: bool,
                  n_ep: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Cut one batch entry down to the ``run_sim`` trace schema: squeeze
    the seed axis when it was implicit, slice the epoch axis to the
    logical count (``None`` = full), and drop the ``hit_rate`` telemetry
    channel for non-PC mechanisms (the traced family computes it for
    all)."""
    ep = slice(None) if n_ep is None else slice(None, n_ep)
    tr = {k: np.asarray(v[w, 0, ep] if squeeze_seed else v[w, :, ep])
          for k, v in arrs.items()}
    if mech not in _PC_MECHS:
        tr.pop("hit_rate", None)
    return tr

# SimConfig fields that may vary across a grid without re-tracing (they map
# onto SimAxes); n_epochs is the *logical* epoch count of a point — the
# executable scans to the grid max and masks the tail.
AXIS_FIELDS = ("epoch_us", "sigma", "cap_per_ghz", "membw", "table_ema",
               "objective", "n_epochs")

# executable-compile counter, keyed by family ("suite_forks", "grid_forks",
# "grid_oracle", ...): incremented at trace time only, so tests and
# benchmarks can assert cache hits / count fork-family compiles per figure.
TRACE_COUNTS: collections.Counter = collections.Counter()


def pad_program(prog: Program, p_max: int) -> Program:
    """Pad ``prog``'s arrays to ``p_max`` blocks without changing semantics.

    The per-block arrays are zero-padded (never gathered past the logical
    length), and the doubled cumulative arrays are rebuilt so indices up to
    ``2 * n_blocks`` — the maximum window extent the execute can request —
    still see the wrap-around copy of the *logical* program, with flat
    padding beyond."""
    P = prog.n_blocks
    if P == p_max:
        return prog
    assert P < p_max, (P, p_max)
    pad1 = jnp.zeros((p_max - P,), jnp.float32)
    pad2 = jnp.zeros((2 * (p_max - P),), jnp.float32)

    def cum(a):
        doubled = jnp.concatenate([jnp.tile(a, 2), pad2])
        return jnp.concatenate([jnp.zeros(1), jnp.cumsum(doubled)])

    arr = lambda a: jnp.concatenate([a, pad1])
    return Program(prog.name, arr(prog.i0_rate), arr(prog.sens_rate),
                   arr(prog.mem_frac),
                   jnp.stack([cum(prog.i0_rate), cum(prog.sens_rate),
                              cum(prog.mem_frac)], axis=-1))


def _stack_programs(progs: Sequence[Program]) -> Tuple[Program, jnp.ndarray]:
    """Pad to a common block count and stack into one batched Program
    (leading workload axis); returns it plus the logical block counts."""
    p_max = max(p.n_blocks for p in progs)
    p_logical = jnp.asarray([p.n_blocks for p in progs], jnp.int32)
    padded = [pad_program(p, p_max) for p in progs]
    stacked = Program(
        "suite",
        *(jnp.stack([getattr(p, f) for p in padded])
          for f in ("i0_rate", "sens_rate", "mem_frac", "cum3")))
    return stacked, p_logical


@functools.partial(jax.jit, static_argnames=("st",))
def _suite_forks(progs: Program, p_logical, seeds, mech_ids, axes: SimAxes,
                 st: SimStatic):
    """(W workloads) x (S seeds) x (M fork mechanisms) in one executable."""
    TRACE_COUNTS["suite_forks"] += 1
    def per_prog(prog, p_blocks):
        def per_seed(seed):
            return jax.vmap(
                lambda m: SIM._scan_sim(prog, p_blocks, seed, st, axes, m)
            )(mech_ids)
        return jax.vmap(per_seed)(seeds)
    return jax.vmap(per_prog)(progs, p_logical)


@functools.partial(jax.jit, static_argnames=("st", "mechanism"))
def _suite_per_mech(progs: Program, p_logical, seeds, axes: SimAxes,
                    st: SimStatic, mechanism: str):
    """(W workloads) x (S seeds) for one statically-specialized mechanism
    (the fork-free static points, and oracle — whose prediction needs this
    epoch's forks and so can't join the fused traced family)."""
    TRACE_COUNTS[f"suite_{mechanism}"] += 1
    def per_prog(prog, p_blocks):
        return jax.vmap(
            lambda seed: SIM._scan_sim(prog, p_blocks, seed, st, axes,
                                       mechanism)
        )(seeds)
    return jax.vmap(per_prog)(progs, p_logical)


def run_suite(programs: Union[Dict[str, Program], Sequence[Program]],
              sim: SimConfig, mechanisms: Sequence[str] = MECHANISMS,
              seeds: Optional[Sequence[int]] = None
              ) -> Dict[str, Dict[str, Dict[str, np.ndarray]]]:
    """Batched-sweep counterpart of calling ``run_sim`` in nested loops.

    Returns ``{workload_name: {mechanism: trace}}`` with the same per-trace
    arrays ``run_sim`` produces. If ``seeds`` is given, every trace array
    gains a leading seed axis; otherwise ``sim.seed`` is used and the axis
    is squeezed away.
    """
    if isinstance(programs, dict):
        names = list(programs)
        progs = [programs[n] for n in names]
    else:
        progs = list(programs)
        names = [p.name for p in progs]
    assert progs, "run_suite needs at least one program"
    for m in mechanisms:
        assert m in MECHANISMS, m
    assert sim.n_cu % sim.cus_per_domain == 0
    squeeze_seed = seeds is None
    seed_arr = jnp.asarray([sim.seed] if seeds is None else list(seeds),
                           jnp.float32)
    stacked, p_logical = _stack_programs(progs)
    st, axes = sim.static_part(), sim.axes()

    fork_mechs = [m for m in mechanisms
                  if m not in _STATIC_MECHS and m != "oracle"]
    by_mech: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fork_mechs:
        ids = jnp.asarray([SIM.FORK_MECH_IDS[m] for m in fork_mechs],
                          jnp.int32)
        ys = _suite_forks(stacked, p_logical, seed_arr, ids, axes, st)
        for j, m in enumerate(fork_mechs):
            by_mech[m] = {k: v[:, :, j] for k, v in ys.items()}
    for m in mechanisms:
        if m in _STATIC_MECHS or m == "oracle":
            by_mech[m] = _suite_per_mech(stacked, p_logical, seed_arr,
                                         axes, st, m)

    out: Dict[str, Dict[str, Dict[str, np.ndarray]]] = {}
    for w, name in enumerate(names):
        out[name] = {m: _unpack_trace(by_mech[m], w, m, squeeze_seed)
                     for m in mechanisms}
    return out


# ---------------------------------------------------------------------------
# Device-sharded grid sweeps
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _grid_exec(st: SimStatic, n_dev: int, mechanism: Optional[str]):
    """Build (once per (SimStatic, device count, family)) the sharded grid
    executable: the flattened (workload x grid-point) axis is split across
    an ``n_dev``-device mesh with ``shard_map`` (identity on one device),
    and each local entry vmaps seeds (x traced fork-mechanism ids when
    ``mechanism`` is None)."""
    mesh = Mesh(np.asarray(jax.local_devices()[:n_dev]), ("i",))
    family = "grid_forks" if mechanism is None else f"grid_{mechanism}"

    @jax.jit
    def dispatch(progs, p_log, axes, seeds, mech_ids):
        TRACE_COUNTS[family] += 1

        def shard_fn(progs_s, p_log_s, axes_s, seeds_s, mech_ids_s):
            def per_entry(prog, p_blocks, ax):
                def per_seed(seed):
                    if mechanism is None:
                        return jax.vmap(
                            lambda m: SIM._scan_sim(prog, p_blocks, seed, st,
                                                    ax, m))(mech_ids_s)
                    return SIM._scan_sim(prog, p_blocks, seed, st, ax,
                                         mechanism)
                return jax.vmap(per_seed)(seeds_s)
            return jax.vmap(per_entry)(progs_s, p_log_s, axes_s)

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P("i"), P("i"), P("i"), P(), P()),
            out_specs=P("i"), check_rep=False,
        )(progs, p_log, axes, seeds, mech_ids)

    return dispatch


def _grid_points(axes_grid) -> Tuple[Tuple[str, ...], List[dict]]:
    """Normalize ``axes_grid`` into (axis names, list of override dicts).

    Dict-of-lists => cartesian product of the values; list-of-dicts =>
    explicit points (for coupled axes like the paper's epoch_us/n_epochs
    granularity sweep). Output keys are the point's values in axis order.
    """
    if isinstance(axes_grid, dict):
        names = tuple(axes_grid)
        for n, vals in axes_grid.items():
            # catch {"objective": "edp"} (product would iterate the chars)
            # and bare scalars with a clean assert instead of a late error
            assert isinstance(vals, (list, tuple)), \
                f"axis {n!r} needs a list of values, got {vals!r}"
        points = [dict(zip(names, combo))
                  for combo in itertools.product(*axes_grid.values())]
        assert points, "axes_grid needs at least one point"  # empty values
    else:
        points = [dict(p) for p in axes_grid]
        assert points, "axes_grid needs at least one point"
        names = tuple(points[0])
        for p in points:
            assert tuple(p) == names, \
                f"grid points must share axes: {tuple(p)} vs {names}"
    for p in points:
        for k in p:
            assert k in AXIS_FIELDS, \
                f"{k!r} is not a traced grid axis (one of {AXIS_FIELDS})"
    return names, points


def _pad_flat(tree, n: int):
    """Pad a pytree's leading (flattened grid) axis to length ``n`` by
    cycling its entries (the pad rows are dropped on unpack)."""
    def pad(a):
        if a.shape[0] >= n:
            return a
        reps = -(-n // a.shape[0])
        return jnp.concatenate([a] * reps, axis=0)[:n]
    return jax.tree.map(pad, tree)


def run_grid(programs: Union[Dict[str, Program], Sequence[Program]],
             static_cfg: SimConfig, axes_grid,
             mechanisms: Sequence[str] = MECHANISMS,
             seeds: Optional[Sequence[int]] = None,
             max_mask_ratio: Optional[float] = None
             ) -> Dict[tuple, Dict[str, Dict[str, Dict[str, np.ndarray]]]]:
    """One executable family for the whole figure grid.

    ``axes_grid`` is either a dict ``{axis: [values...]}`` whose values are
    cartesian-producted, or an explicit list of ``{axis: value}`` points
    (coupled axes); axes are the traced ``SimConfig`` fields in
    ``AXIS_FIELDS``. ``static_cfg`` supplies the static shape/flag fields
    and the default value of every axis not named in the grid.

    Each grid point's ``SimAxes`` (with ``n_epochs`` as its logical epoch
    count — the scan runs to the grid max and the tail is masked/sliced)
    is stacked and vmapped alongside workloads x seeds x mechanism ids;
    the flattened (workload x grid-point) axis is sharded across local
    devices with ``shard_map`` (1-device mesh = identity). Fork--pre-
    execute mechanisms share one traced-id executable, oracle gets its
    specialized one, static frequencies one each — for any grid size.

    When logical epoch counts are strongly coupled to an axis (the paper's
    granularity sweeps pair 1 us with 6x the epochs of 100 us), scanning
    every point to the grid max wastes masked-tail compute;
    ``max_mask_ratio`` bounds that waste by partitioning the points into
    buckets whose max/min ``n_epochs`` ratio stays below it (one
    executable family per bucket, same merged result dict). ``None``
    keeps the whole grid in a single executable family.

    Returns ``{grid_key: {workload: {mechanism: trace}}}`` where
    ``grid_key`` is the tuple of the point's axis values in axis order and
    each trace matches the per-point ``run_suite`` output (seed axis
    squeezed unless ``seeds`` is given, epoch axis cut to the point's
    logical ``n_epochs``).
    """
    if isinstance(programs, dict):
        names_w = list(programs)
        progs = [programs[n] for n in names_w]
    else:
        progs = list(programs)
        names_w = [p.name for p in progs]
    assert progs, "run_grid needs at least one program"
    for m in mechanisms:
        assert m in MECHANISMS, m
    assert static_cfg.n_cu % static_cfg.cus_per_domain == 0
    axis_names, points = _grid_points(axes_grid)
    keys = [tuple(p[n] for n in axis_names) for p in points]
    assert len(set(keys)) == len(keys), "duplicate grid points"

    if max_mask_ratio is not None and len(points) > 1:
        assert max_mask_ratio >= 1.0, max_mask_ratio
        buckets: List[List[dict]] = []
        for p in sorted(points, reverse=True,
                        key=lambda p: p.get("n_epochs", static_cfg.n_epochs)):
            n_ep = p.get("n_epochs", static_cfg.n_epochs)
            b_max = buckets[-1][0].get("n_epochs", static_cfg.n_epochs) \
                if buckets else None
            if buckets and b_max / n_ep <= max_mask_ratio:
                buckets[-1].append(p)
            else:
                buckets.append([p])
        if len(buckets) > 1:
            out: Dict[tuple, Dict] = {}
            for bucket in buckets:
                out.update(run_grid(programs, static_cfg, bucket,
                                    mechanisms, seeds))
            # restore the caller's grid-point order
            return {k: out[k] for k in keys}

    squeeze_seed = seeds is None
    seed_arr = jnp.asarray(
        [static_cfg.seed] if seeds is None else list(seeds), jnp.float32)
    stacked, p_logical = _stack_programs(progs)
    W, G = len(progs), len(points)

    sims = [dataclasses.replace(static_cfg, **p) for p in points]
    n_ep_max = max(s.n_epochs for s in sims)
    st = static_cfg.static_part(n_epochs=n_ep_max)
    axes_g = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[s.axes() for s in sims])

    # flatten workload-major: flat index i = w * G + g
    progs_flat = jax.tree.map(lambda a: jnp.repeat(a, G, axis=0), stacked)
    p_log_flat = jnp.repeat(p_logical, G, axis=0)
    axes_flat = jax.tree.map(
        lambda a: jnp.tile(a, (W,) + (1,) * (a.ndim - 1)), axes_g)

    n_flat = W * G
    n_dev = jax.local_device_count()
    n_pad = -(-n_flat // n_dev) * n_dev
    if n_pad != n_flat:
        progs_flat = _pad_flat(progs_flat, n_pad)
        p_log_flat = _pad_flat(p_log_flat, n_pad)
        axes_flat = _pad_flat(axes_flat, n_pad)

    fork_mechs = [m for m in mechanisms
                  if m not in _STATIC_MECHS and m != "oracle"]
    by_mech: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fork_mechs:
        ids = jnp.asarray([SIM.FORK_MECH_IDS[m] for m in fork_mechs],
                          jnp.int32)
        ys = _grid_exec(st, n_dev, None)(progs_flat, p_log_flat, axes_flat,
                                         seed_arr, ids)
        for j, m in enumerate(fork_mechs):
            by_mech[m] = {k: v[:, :, j] for k, v in ys.items()}
    no_ids = jnp.zeros((0,), jnp.int32)  # specialized mechs ignore mech_ids
    for m in mechanisms:
        if m in _STATIC_MECHS or m == "oracle":
            by_mech[m] = _grid_exec(st, n_dev, m)(
                progs_flat, p_log_flat, axes_flat, seed_arr, no_ids)

    out: Dict[tuple, Dict[str, Dict[str, Dict[str, np.ndarray]]]] = {}
    for g, (key, sim_pt) in enumerate(zip(keys, sims)):
        out[key] = {}
        for w, name in enumerate(names_w):
            i = w * G + g
            out[key][name] = {
                m: _unpack_trace(by_mech[m], i, m, squeeze_seed,
                                 n_ep=sim_pt.n_epochs) for m in mechanisms}
    return out


def suite_metrics(programs: Union[Dict[str, Program], Sequence[Program]],
                  sim: SimConfig, mechanisms: Sequence[str] = MECHANISMS,
                  n: int = 2,
                  traces: Optional[Dict] = None
                  ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Batched counterpart of ``run_workload`` over a whole suite: ED^nP
    normalized to static17 per workload. Pass ``traces`` (a ``run_suite``
    result that includes static17) to reuse already-computed traces."""
    mechs = tuple(mechanisms)
    if traces is None:
        need = mechs if "static17" in mechs else ("static17",) + mechs
        traces = run_suite(programs, sim, need)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for name, trs in traces.items():
        base = trs["static17"]
        budget = 0.9 * base["work"].sum()
        E0, D0, M0 = ednp(base, budget, sim.epoch_us, n)
        out[name] = {}
        for m in mechs:
            E, D, M = ednp(trs[m], budget, sim.epoch_us, n)
            out[name][m] = {
                "accuracy": prediction_accuracy(trs[m])
                if m not in _STATIC_MECHS else float("nan"),
                "E": E, "D": D, "ednp": M, "ednp_norm": M / M0,
                "energy_norm": E / E0, "delay_norm": D / D0,
            }
    return out
