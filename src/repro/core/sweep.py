"""Batched sweep layer: one compiled executable per mechanism *family*
instead of one trace per (workload, mechanism, seed) tuple.

The paper's headline figures (14/15/18) sweep ~10 mechanisms x ~10 workloads
(x epoch granularities x objectives) through the fork--pre-execute engine.
Run serially that is ~100 scan traces; ``run_suite`` instead

  1. pads every ``Program`` to a common block count (``pad_program`` keeps
     the wrapped prefix-sum window semantics exact by rebuilding the doubled
     cumulative arrays at the *logical* length before padding, and threads
     the logical block count through the scan as a traced scalar);
  2. stacks the padded programs into one pytree and ``vmap``s the
     simulation scan across workloads and seeds (both traced: the noise
     hash takes the seed as a scalar operand);
  3. vmaps across mechanisms *within a family*: all fork--pre-execute
     mechanisms (``simulate.FORK_MECHS``) share a shape-identical carry and
     run as one executable indexed by a traced mechanism id, while the
     static-frequency mechanisms compile to their own (fork-free, ~10x
     cheaper) executable per frequency.

A full Fig-15 sweep is therefore a handful of XLA executables — typically
one fork-family compile plus one per requested static point — and repeated
sweeps with the same ``SimConfig`` hit the jit cache and never re-trace.

Execution-model / caching contract: see ``repro.core.simulate``'s module
docstring; ``run_suite`` output is numerically equivalent to calling
``run_sim`` per (workload, mechanism, seed) — the per-row math is identical
and batched reductions preserve per-row ordering (tested to 1e-5 by
``tests/test_sweep.py``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate as SIM
from repro.core.simulate import MECHANISMS, SimConfig, ednp, prediction_accuracy
from repro.core.workloads import Program

_STATIC_MECHS = ("static13", "static17", "static22")


def pad_program(prog: Program, p_max: int) -> Program:
    """Pad ``prog``'s arrays to ``p_max`` blocks without changing semantics.

    The per-block arrays are zero-padded (never gathered past the logical
    length), and the doubled cumulative arrays are rebuilt so indices up to
    ``2 * n_blocks`` — the maximum window extent the execute can request —
    still see the wrap-around copy of the *logical* program, with flat
    padding beyond."""
    P = prog.n_blocks
    if P == p_max:
        return prog
    assert P < p_max, (P, p_max)
    pad1 = jnp.zeros((p_max - P,), jnp.float32)
    pad2 = jnp.zeros((2 * (p_max - P),), jnp.float32)

    def cum(a):
        doubled = jnp.concatenate([jnp.tile(a, 2), pad2])
        return jnp.concatenate([jnp.zeros(1), jnp.cumsum(doubled)])

    arr = lambda a: jnp.concatenate([a, pad1])
    return Program(prog.name, arr(prog.i0_rate), arr(prog.sens_rate),
                   arr(prog.mem_frac), cum(prog.i0_rate),
                   cum(prog.sens_rate), cum(prog.mem_frac))


def _stack_programs(progs: Sequence[Program]) -> Tuple[Program, jnp.ndarray]:
    """Pad to a common block count and stack into one batched Program
    (leading workload axis); returns it plus the logical block counts."""
    p_max = max(p.n_blocks for p in progs)
    p_logical = jnp.asarray([p.n_blocks for p in progs], jnp.int32)
    padded = [pad_program(p, p_max) for p in progs]
    stacked = Program(
        "suite",
        *(jnp.stack([getattr(p, f) for p in padded])
          for f in ("i0_rate", "sens_rate", "mem_frac",
                    "cum_i0", "cum_sens", "cum_mem")))
    return stacked, p_logical


@functools.partial(jax.jit, static_argnames=("sim",))
def _suite_forks(progs: Program, p_logical, seeds, mech_ids, sim: SimConfig):
    """(W workloads) x (S seeds) x (M fork mechanisms) in one executable."""
    def per_prog(prog, p_blocks):
        def per_seed(seed):
            return jax.vmap(
                lambda m: SIM._scan_sim(prog, p_blocks, seed, sim, m)
            )(mech_ids)
        return jax.vmap(per_seed)(seeds)
    return jax.vmap(per_prog)(progs, p_logical)


@functools.partial(jax.jit, static_argnames=("sim", "mechanism"))
def _suite_per_mech(progs: Program, p_logical, seeds, sim: SimConfig,
                    mechanism: str):
    """(W workloads) x (S seeds) for one statically-specialized mechanism
    (the fork-free static points, and oracle — whose prediction needs this
    epoch's forks and so can't join the fused traced family)."""
    def per_prog(prog, p_blocks):
        return jax.vmap(
            lambda seed: SIM._scan_sim(prog, p_blocks, seed, sim, mechanism)
        )(seeds)
    return jax.vmap(per_prog)(progs, p_logical)


def run_suite(programs: Union[Dict[str, Program], Sequence[Program]],
              sim: SimConfig, mechanisms: Sequence[str] = MECHANISMS,
              seeds: Optional[Sequence[int]] = None
              ) -> Dict[str, Dict[str, Dict[str, np.ndarray]]]:
    """Batched-sweep counterpart of calling ``run_sim`` in nested loops.

    Returns ``{workload_name: {mechanism: trace}}`` with the same per-trace
    arrays ``run_sim`` produces. If ``seeds`` is given, every trace array
    gains a leading seed axis; otherwise ``sim.seed`` is used and the axis
    is squeezed away.
    """
    if isinstance(programs, dict):
        names = list(programs)
        progs = [programs[n] for n in names]
    else:
        progs = list(programs)
        names = [p.name for p in progs]
    assert progs, "run_suite needs at least one program"
    for m in mechanisms:
        assert m in MECHANISMS, m
    assert sim.n_cu % sim.cus_per_domain == 0
    squeeze_seed = seeds is None
    seed_arr = jnp.asarray([sim.seed] if seeds is None else list(seeds),
                           jnp.float32)
    stacked, p_logical = _stack_programs(progs)

    fork_mechs = [m for m in mechanisms
                  if m not in _STATIC_MECHS and m != "oracle"]
    by_mech: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fork_mechs:
        ids = jnp.asarray([SIM.FORK_MECH_IDS[m] for m in fork_mechs],
                          jnp.int32)
        ys = _suite_forks(stacked, p_logical, seed_arr, ids, sim)
        for j, m in enumerate(fork_mechs):
            by_mech[m] = {k: v[:, :, j] for k, v in ys.items()}
    for m in mechanisms:
        if m in _STATIC_MECHS or m == "oracle":
            by_mech[m] = _suite_per_mech(stacked, p_logical, seed_arr, sim, m)

    out: Dict[str, Dict[str, Dict[str, np.ndarray]]] = {}
    for w, name in enumerate(names):
        out[name] = {}
        for m in mechanisms:
            tr = {k: np.asarray(v[w, 0] if squeeze_seed else v[w])
                  for k, v in by_mech[m].items()}
            if m not in ("pcstall", "accpc"):
                # match run_sim's trace schema: hit_rate is a PC-mechanism
                # telemetry channel (the traced family computes it for all)
                tr.pop("hit_rate", None)
            out[name][m] = tr
    return out


def suite_metrics(programs: Union[Dict[str, Program], Sequence[Program]],
                  sim: SimConfig, mechanisms: Sequence[str] = MECHANISMS,
                  n: int = 2,
                  traces: Optional[Dict] = None
                  ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Batched counterpart of ``run_workload`` over a whole suite: ED^nP
    normalized to static17 per workload. Pass ``traces`` (a ``run_suite``
    result that includes static17) to reuse already-computed traces."""
    mechs = tuple(mechanisms)
    if traces is None:
        need = mechs if "static17" in mechs else ("static17",) + mechs
        traces = run_suite(programs, sim, need)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for name, trs in traces.items():
        base = trs["static17"]
        budget = 0.9 * base["work"].sum()
        E0, D0, M0 = ednp(base, budget, sim.epoch_us, n)
        out[name] = {}
        for m in mechs:
            E, D, M = ednp(trs[m], budget, sim.epoch_us, n)
            out[name][m] = {
                "accuracy": prediction_accuracy(trs[m])
                if m not in _STATIC_MECHS else float("nan"),
                "E": E, "D": D, "ednp": M, "ednp_norm": M / M0,
                "energy_norm": E / E0, "delay_norm": D / D0,
            }
    return out
