"""Batched sweep layer: ONE dispatch path — the device-sharded grid
executable family — for every sweep, from a single ``run_suite`` call to a
whole figure grid.

The paper's headline figures (14/15/17/18) sweep ~10 mechanisms x ~10
workloads x epoch granularities x objectives through the fork--pre-execute
engine. Run serially that is hundreds of scan traces; this layer instead

  1. pads every ``Program`` to a common block count (``pad_program`` keeps
     the wrapped prefix-sum window semantics exact by rebuilding the doubled
     cumulative arrays at the *logical* length before padding, and threads
     the logical block count through the scan as a traced scalar);
  2. stacks whole ``SimAxes`` grid points — epoch_us, sigma, capacity,
     bandwidth, EMA, lowered objective, logical epoch count — along a
     leading axis, cartesian-products them with the workloads, and shards
     the flattened (workload x grid-point) axis across local devices with
     ``shard_map`` (a 1-device mesh is the identity layout). Points with
     fewer logical epochs scan to the grid max and mask the tail, the same
     pad-and-mask move applied to programs;
  3. vmaps seeds and, within the fork family, mechanisms: all traced
     fork--pre-execute mechanisms (``simulate.FORK_MECHS``, ids frozen by
     the ``repro.core.mechanisms`` registry) share a shape-identical carry
     and run as executables indexed by a traced mechanism id, while oracle
     (whose prediction needs this epoch's forks), the static frequencies
     and registered custom mechanisms (``MechanismSpec.predict`` hooks)
     compile to their own specialized executables;
  4. deduplicates every mechanism across grid points by its spec's
     declared live axes (``MechanismSpec.exec_axes``): points agreeing on
     a mechanism's live axes form one equivalence class and share one
     scan, broadcast back to every member grid key. A static frequency
     never reads the objective or the table EMA (a 3-objective grid would
     otherwise triple static-mech compute for bitwise-identical traces);
     reactive (table-free) mechanisms and oracle never read the table EMA,
     so a table_ema-only grid axis stops multiplying their rows too.
     Traced mechanisms inducing the same point partition share one
     dispatch — on a grid with no dead axes the whole fork family is ONE
     dispatch over the full operands, exactly as before the spec redesign.
     The ``power`` axis (a swept IVR/hardware regime, ``PowerConfig``
     values) is live for EVERY mechanism — the V/f ladder and the energy
     accounting read it even for a static frequency — so power classes
     never collapse; only the other dead axes around them do;
     ``DISPATCH_ROWS`` records the logical scan rows actually executed per
     family (the dedup savings show up here);
  5. builds the initial scan carry outside the executables
     (``simulate.init_carry``, jitted once per ``SimStatic``) and donates
     it (``donate_argnums``), so the runtime can release the carry buffers
     as soon as the scan consumes them instead of pinning a protected
     input copy for the whole dispatch.

``run_suite`` IS a 1-point ``run_grid``: there is no parallel suite
executable family, so every consumer — figures, benchmarks, the DVFS
runtime manager, examples — dispatches through the same executables and
cross-path comparisons are bitwise by construction. A full
Fig-15/17/18-style sweep over several epoch granularities and objectives is
at most two fork-family executables (the traced-id family plus oracle's
specialized one) plus one per static mechanism; repeated sweeps with the
same ``SimStatic`` and grid shape hit the jit cache and never re-trace
(``TRACE_COUNTS`` records compiles for tests/benchmarks).

Execution-model / caching contract: see ``repro.core.simulate``'s module
docstring. The only remaining cross-family numerics boundary is the
specialized per-mechanism ``run_sim`` string-mech trace: its math is
identical to the traced-id family at the jaxpr level, but XLA may fuse f32
chains differently, and the resulting last-ulp differences can compound
through the closed control loop over hundreds of epochs (rarely enough to
flip a frequency decision, after which traces genuinely separate).
``run_suite``/``run_grid`` results agree with ``run_sim`` to f32 exactness
(tested to 1e-5 by ``tests/test_sweep.py``); comparisons *among* sweep-layer
results need no tolerance at all (bitwise, ``tests/test_grid.py``).

Pallas kernels (``SimConfig.use_pallas``) are an *opt-in engine mode* of
this layer: under v2 the traced-mechanism-id family scans the fused epoch
kernel (``kernels.epoch_fused`` in its ``family="fork"`` mode, which
multiplexes every traced mechanism behind one traced id) inside the SAME
vmapped, shard_map'd executables — the engine switch lives in
``simulate._scan_sim`` keyed off ``SimStatic.use_pallas``, so the ≤2
fork-family-compile and ``DISPATCH_ROWS`` dedup contracts above are
unchanged. Specs the kernel cannot serve (static pins, oracle, custom
predict hooks — ``MechanismSpec.v2_capable`` is False) silently fall back
to the jnp body inside their own specialized executables. The DEFAULT
(``use_pallas=False``) grid path still runs the pure-jnp scan body and
stays bitwise against ``tests/data/grid_reference.npz``; v2 results are
held to the PR-6 aggregate tolerances instead (XLA cannot be forced to
reproduce the fused kernel's op order; ``lean=False`` pins the exact
reference op order for scan-equivalence tests). ``SimConfig.pallas_block_cu``
additionally selects the blocked ``(CU,)``-grid kernel pair for large CU
counts (fork family only, lean math; ignored on the direct-eval interpret
engine).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import threading
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import mechanisms as MECH
from repro.core import power as PWR
from repro.core import simulate as SIM
from repro.launch.mesh import grid_mesh
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import (MECHANISMS, SimConfig, SimStatic, ednp,
                                 prediction_accuracy)
from repro.core.workloads import Program

# Back-compat alias: the SimAxes fields a static-frequency mechanism's
# trace depends on. Since the spec redesign this is just the static
# builtin's declared ``exec_axes`` (minus the specially-handled logical
# epoch count) — the dedup below is generic over every spec's axes.
STATIC_EXEC_AXES = MECH.get("static17").dedup_axes


def _unpack_trace(arrs: Dict[str, jnp.ndarray], i: int, spec: MechanismSpec,
                  squeeze_seed: bool,
                  n_ep: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Cut flat-row ``i`` of a batch down to the ``run_sim`` trace schema:
    squeeze the seed axis when it was implicit, slice the epoch axis to the
    logical count (``None`` = full), and drop the ``hit_rate`` telemetry
    channel for mechanisms whose spec doesn't declare it (the traced
    family computes it for all; registered PC-family mechanisms get the
    channel by setting ``hit_telemetry`` — no sweep-layer edit needed)."""
    ep = slice(None) if n_ep is None else slice(None, n_ep)
    tr = {k: np.asarray(v[i, 0, ep] if squeeze_seed else v[i, :, ep])
          for k, v in arrs.items()}
    if not spec.hit_telemetry:
        tr.pop("hit_rate", None)
    return tr

# SimConfig fields that may vary across a grid without re-tracing (they map
# onto SimAxes); n_epochs is the *logical* epoch count of a point — the
# executable scans to the grid max and masks the tail. ``power`` values
# are whole ``power.PowerConfig`` regimes (traced except the ladder
# length ``n_freqs``, which sets shapes and must be grid-constant).
AXIS_FIELDS = ("epoch_us", "sigma", "cap_per_ghz", "membw", "table_ema",
               "objective", "n_epochs", "power")

# executable-compile counter, keyed by family ("grid_forks", "grid_oracle",
# "grid_static17", ...): incremented at trace time only, so tests and
# benchmarks can assert cache hits / count fork-family compiles per figure.
TRACE_COUNTS: collections.Counter = collections.Counter()

# logical (workload x grid-point x mechanism) scan rows dispatched per
# family, incremented on every dispatch (cached or not): the spec-driven
# dedup shows up here as W x n_classes rows per mechanism instead of
# W x n_points — for static mechanisms AND for any fork mechanism whose
# ``exec_axes`` make a grid axis dead (e.g. reactive mechanisms on a
# table_ema-only axis).
DISPATCH_ROWS: collections.Counter = collections.Counter()

# Counter increments are read-modify-write: the DVFSService dispatches
# grids from worker threads, so unlocked `+=` would drop updates. Every
# mutation of the two counters above takes this lock; snapshot reads
# (``dict(TRACE_COUNTS)``) are safe without it.
_COUNTER_LOCK = threading.Lock()


def reset_counters() -> None:
    """Zero ``TRACE_COUNTS`` and ``DISPATCH_ROWS`` atomically. Tests and
    benchmarks use this instead of ad-hoc ``.clear()`` calls so the reset
    cannot interleave with a concurrent dispatch's increment."""
    with _COUNTER_LOCK:
        TRACE_COUNTS.clear()
        DISPATCH_ROWS.clear()


def pad_program(prog: Program, p_max: int) -> Program:
    """Pad ``prog``'s arrays to ``p_max`` blocks without changing semantics.

    The per-block arrays are zero-padded (never gathered past the logical
    length), and the doubled cumulative arrays are rebuilt so indices up to
    ``2 * n_blocks`` — the maximum window extent the execute can request —
    still see the wrap-around copy of the *logical* program, with flat
    padding beyond."""
    P = prog.n_blocks
    if P == p_max:
        return prog
    assert P < p_max, (P, p_max)
    pad1 = jnp.zeros((p_max - P,), jnp.float32)
    pad2 = jnp.zeros((2 * (p_max - P),), jnp.float32)

    def cum(a):
        doubled = jnp.concatenate([jnp.tile(a, 2), pad2])
        return jnp.concatenate([jnp.zeros(1), jnp.cumsum(doubled)])

    arr = lambda a: jnp.concatenate([a, pad1])
    return Program(prog.name, arr(prog.i0_rate), arr(prog.sens_rate),
                   arr(prog.mem_frac),
                   jnp.stack([cum(prog.i0_rate), cum(prog.sens_rate),
                              cum(prog.mem_frac)], axis=-1))


def _stack_programs(progs: Sequence[Program]) -> Tuple[Program, jnp.ndarray]:
    """Pad to a common block count and stack into one batched Program
    (leading workload axis); returns it plus the logical block counts."""
    p_max = max(p.n_blocks for p in progs)
    p_logical = jnp.asarray([p.n_blocks for p in progs], jnp.int32)
    padded = [pad_program(p, p_max) for p in progs]
    stacked = Program(
        "suite",
        *(jnp.stack([getattr(p, f) for p in padded])
          for f in ("i0_rate", "sens_rate", "mem_frac", "cum3")))
    return stacked, p_logical


# ---------------------------------------------------------------------------
# The grid executable family — the only dispatch path
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _grid_exec(st: SimStatic, n_dev: int,
               mechanism: Optional[MechanismSpec]):
    """Build (once per (SimStatic, device count, family)) the sharded grid
    executable: the flattened (workload x grid-point) axis is split across
    an ``n_dev``-device mesh with ``shard_map`` (identity on one device),
    and each local entry vmaps seeds (x traced fork-mechanism ids when
    ``mechanism`` is None). ``mechanism`` is a spec for the specialized
    families — static frequencies, oracle, and registered custom
    mechanisms (whose predict/update hooks trace in here without any
    sweep-layer change). The initial scan carry arrives pre-built and
    donated (see ``simulate.init_carry``)."""
    mesh = grid_mesh(n_dev)   # built once per process (launch.mesh)
    family = "grid_forks" if mechanism is None else f"grid_{mechanism.name}"

    @functools.partial(jax.jit, donate_argnums=(0,))
    def dispatch(carry0, progs, p_log, axes, seeds, mech_ids):
        with _COUNTER_LOCK:  # trace-time side effect; threads dispatch
            TRACE_COUNTS[family] += 1

        def shard_fn(carry0_s, progs_s, p_log_s, axes_s, seeds_s,
                     mech_ids_s):
            def per_entry(c0, prog, p_blocks, ax):
                def per_seed(seed):
                    if mechanism is None:
                        return jax.vmap(
                            lambda m: SIM._scan_sim(prog, p_blocks, seed, st,
                                                    ax, m, carry0=c0)
                        )(mech_ids_s)
                    return SIM._scan_sim(prog, p_blocks, seed, st, ax,
                                         mechanism, carry0=c0)
                return jax.vmap(per_seed)(seeds_s)
            return jax.vmap(per_entry)(carry0_s, progs_s, p_log_s, axes_s)

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P("i"), P("i"), P("i"), P("i"), P(), P()),
            out_specs=P("i"), check_rep=False,
        )(carry0, progs, p_log, axes, seeds, mech_ids)

    return dispatch


def _grid_points(axes_grid) -> Tuple[Tuple[str, ...], List[dict]]:
    """Normalize ``axes_grid`` into (axis names, list of override dicts).

    Dict-of-lists => cartesian product of the values; list-of-dicts =>
    explicit points (for coupled axes like the paper's epoch_us/n_epochs
    granularity sweep). Points must share the same axis *set*; their key
    insertion order is normalized to the first point's (callers building
    points from heterogeneous sources are describing the same grid).
    Output keys are the point's values in axis order.
    """
    if isinstance(axes_grid, dict):
        names = tuple(axes_grid)
        for n, vals in axes_grid.items():
            # catch {"objective": "edp"} (product would iterate the chars)
            # and bare scalars with a clean assert instead of a late error
            assert isinstance(vals, (list, tuple)), \
                f"axis {n!r} needs a list of values, got {vals!r}"
        points = [dict(zip(names, combo))
                  for combo in itertools.product(*axes_grid.values())]
        assert points, "axes_grid needs at least one point"  # empty values
    else:
        points = [dict(p) for p in axes_grid]
        assert points, "axes_grid needs at least one point"
        names = tuple(points[0])
        for p in points:
            assert set(p) == set(names), \
                f"grid points must share axes: {sorted(p)} vs {sorted(names)}"
        points = [{n: p[n] for n in names} for p in points]
    for p in points:
        for k in p:
            assert k in AXIS_FIELDS, \
                f"{k!r} is not a traced grid axis (one of {AXIS_FIELDS})"
            if k == "power":
                assert isinstance(p[k], PWR.PowerConfig), \
                    f"power axis values must be PowerConfig, got {p[k]!r}"
    return names, points


def _pad_flat(tree, n: int):
    """Pad a pytree's leading (flattened grid) axis to length ``n`` by
    cycling its entries (the pad rows are dropped on unpack)."""
    def pad(a):
        if a.shape[0] >= n:
            return a
        reps = -(-n // a.shape[0])
        return jnp.concatenate([a] * reps, axis=0)[:n]
    return jax.tree.map(pad, tree)


def _flat_operands(stacked: Program, p_logical: jnp.ndarray,
                   sims: Sequence[SimConfig], n_dev: int):
    """Flatten workload-major (flat index i = w * G + g for G grid points)
    and pad the flat axis to a device multiple for ``shard_map``."""
    W, G = int(p_logical.shape[0]), len(sims)
    axes_g = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[s.axes() for s in sims])
    progs_flat = jax.tree.map(lambda a: jnp.repeat(a, G, axis=0), stacked)
    p_log_flat = jnp.repeat(p_logical, G, axis=0)
    axes_flat = jax.tree.map(
        lambda a: jnp.tile(a, (W,) + (1,) * (a.ndim - 1)), axes_g)
    n_flat = W * G
    n_pad = -(-n_flat // n_dev) * n_dev
    if n_pad != n_flat:
        progs_flat = _pad_flat(progs_flat, n_pad)
        p_log_flat = _pad_flat(p_log_flat, n_pad)
        axes_flat = _pad_flat(axes_flat, n_pad)
    return progs_flat, p_log_flat, axes_flat, n_flat


@functools.lru_cache(maxsize=None)
def _carry_builder(st: SimStatic):
    """Jitted batched ``init_carry`` (compiled once per SimStatic + flat
    shape): the carry is rebuilt on every dispatch because it is donated,
    so the build itself must not re-trace on the warm path."""
    return jax.jit(jax.vmap(lambda pb: SIM.init_carry(pb, st)))


def _run_family(st: SimStatic, n_dev: int,
                mechanism: Optional[MechanismSpec],
                operands, seed_arr: jnp.ndarray, mech_ids: jnp.ndarray
                ) -> Dict[str, jnp.ndarray]:
    """Dispatch one executable family over pre-flattened grid operands."""
    progs_flat, p_log_flat, axes_flat, n_flat = operands
    family = "grid_forks" if mechanism is None else f"grid_{mechanism.name}"
    with _COUNTER_LOCK:
        DISPATCH_ROWS[family] += n_flat * max(int(mech_ids.shape[0]), 1)
    # the initial scan carry is rebuilt per dispatch: it is donated to the
    # executable, which invalidates its buffers
    carry0 = _carry_builder(st)(p_log_flat)
    with warnings.catch_warnings():
        # The donated carry can never alias into the executable's outputs
        # (the traces carry epoch/seed/mech axes the carry lacks), so XLA's
        # "not usable" warning is expected by construction on every
        # backend; donation still releases the init buffers to the runtime
        # as soon as the scan consumes them instead of pinning them for
        # the whole dispatch.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _grid_exec(st, n_dev, mechanism)(
            carry0, progs_flat, p_log_flat, axes_flat, seed_arr, mech_ids)


def _exec_classes(sims: Sequence[SimConfig], dedup_axes: Tuple[str, ...]
                  ) -> Tuple[List[int], List[SimConfig]]:
    """Partition grid points into equivalence classes of a mechanism's
    live axes (``MechanismSpec.dedup_axes`` — its declared ``exec_axes``
    mapped to SimConfig fields, minus the logical epoch count): points
    agreeing on every live axis produce bitwise-identical traces, so the
    mechanism scans once per class.

    Returns ``(class_of, class_sims)``: ``class_of[g]`` is the class index
    of point ``g``, and ``class_sims[c]`` the class representative — the
    point's execution-relevant axes with the class-max logical epoch count
    (the mask only zeroes outputs past ``n_ep``; the scan state is causal,
    so every member's trace is a prefix slice of the representative's)."""
    class_of: List[int] = []
    class_sims: List[SimConfig] = []
    index: Dict[tuple, int] = {}
    for s in sims:
        ck = tuple(getattr(s, a) for a in dedup_axes)
        c = index.setdefault(ck, len(class_sims))
        if c == len(class_sims):
            class_sims.append(s)
        elif s.n_epochs > class_sims[c].n_epochs:
            class_sims[c] = s
        class_of.append(c)
    return class_of, class_sims


def run_suite(programs: Union[Dict[str, Program], Sequence[Program]],
              sim: SimConfig,
              mechanisms: Sequence[Union[str, MechanismSpec]] = MECHANISMS,
              seeds: Optional[Sequence[int]] = None
              ) -> Dict[str, Dict[str, Dict[str, np.ndarray]]]:
    """Batched-sweep counterpart of calling ``run_sim`` in nested loops.

    This IS a 1-point ``run_grid`` — same executables, same numerics, no
    parallel dispatch family. Returns ``{workload_name: {mechanism: trace}}``
    with the same per-trace arrays ``run_sim`` produces. If ``seeds`` is
    given, every trace array gains a leading seed axis; otherwise
    ``sim.seed`` is used and the axis is squeezed away.
    """
    return run_grid(programs, sim, [{}], mechanisms, seeds)[()]


def run_grid(programs: Union[Dict[str, Program], Sequence[Program]],
             static_cfg: SimConfig, axes_grid,
             mechanisms: Sequence[Union[str, MechanismSpec]] = MECHANISMS,
             seeds: Optional[Sequence[int]] = None,
             max_mask_ratio: Optional[float] = None,
             dedup: bool = True
             ) -> Dict[tuple, Dict[str, Dict[str, Dict[str, np.ndarray]]]]:
    """One executable family for the whole figure grid.

    ``axes_grid`` is either a dict ``{axis: [values...]}`` whose values are
    cartesian-producted, or an explicit list of ``{axis: value}`` points
    (coupled axes); axes are the traced ``SimConfig`` fields in
    ``AXIS_FIELDS``. ``static_cfg`` supplies the static shape/flag fields
    and the default value of every axis not named in the grid.
    ``mechanisms`` are registered names or ``MechanismSpec`` values
    (resolved uniformly through ``repro.core.mechanisms``); results are
    keyed by spec name.

    Each grid point's ``SimAxes`` (with ``n_epochs`` as its logical epoch
    count — the scan runs to the grid max and the tail is masked/sliced)
    is stacked and vmapped alongside workloads x seeds x mechanism ids;
    the flattened (workload x grid-point) axis is sharded across local
    devices with ``shard_map`` (1-device mesh = identity). Traced
    fork--pre-execute mechanisms share executables; oracle, static
    frequencies and registered custom mechanisms compile specialized ones
    — for any grid size. Every mechanism is deduplicated across grid
    points by its spec's declared live axes (``MechanismSpec.exec_axes``):
    it scans once per equivalence class of points agreeing on those axes
    and the class trace is broadcast back to every member's grid key
    (bitwise — the other axes are dead inputs to its executable). A
    static frequency collapses objective and table_ema axes; a reactive
    (table-free) mechanism and oracle collapse table_ema axes; PC
    mechanisms consume every axis; a swept ``power`` regime (the traced
    IVR hardware point, ``PowerConfig`` values sharing one ladder length)
    is live for everyone and never collapses. ``dedup=False`` forces one
    scan per (mechanism x grid point), for A/B benchmarking.

    When logical epoch counts are strongly coupled to an axis (the paper's
    granularity sweeps pair 1 us with 6x the epochs of 100 us), scanning
    every point to the grid max wastes masked-tail compute;
    ``max_mask_ratio`` bounds that waste by partitioning the points into
    buckets whose max/min ``n_epochs`` ratio stays below it (one
    executable family per bucket, same merged result dict). ``None``
    keeps the whole grid in a single executable family.

    Returns ``{grid_key: {workload: {mechanism: trace}}}`` where
    ``grid_key`` is the tuple of the point's axis values in axis order and
    each trace matches the per-point ``run_suite`` output (seed axis
    squeezed unless ``seeds`` is given, epoch axis cut to the point's
    logical ``n_epochs``).
    """
    if isinstance(programs, dict):
        names_w = list(programs)
        progs = [programs[n] for n in names_w]
    else:
        progs = list(programs)
        names_w = [p.name for p in progs]
    assert progs, "run_grid needs at least one program"
    specs = [MECH.resolve(m) for m in mechanisms]
    if dedup:
        # Refuse under-declared specs BEFORE any dispatch: dedup
        # broadcasts one scan across every grid point agreeing on a
        # spec's declared live axes, so a trace reading an undeclared
        # axis would get silently wrong results. The audit (a tiny
        # make_jaxpr, no compile) is cached per spec per process —
        # builtins and repeat grids pay nothing after the first call.
        from repro.analysis.deps import require_dedup_sound
        for s in specs:
            require_dedup_sound(s)
    assert static_cfg.n_cu % static_cfg.cus_per_domain == 0
    axis_names, points = _grid_points(axes_grid)
    keys = [tuple(p[n] for n in axis_names) for p in points]
    assert len(set(keys)) == len(keys), "duplicate grid points"

    if max_mask_ratio is not None and len(points) > 1:
        assert max_mask_ratio >= 1.0, max_mask_ratio
        buckets: List[List[dict]] = []
        for p in sorted(points, reverse=True,
                        key=lambda p: p.get("n_epochs", static_cfg.n_epochs)):
            n_ep = p.get("n_epochs", static_cfg.n_epochs)
            b_max = buckets[-1][0].get("n_epochs", static_cfg.n_epochs) \
                if buckets else None
            if buckets and b_max / n_ep <= max_mask_ratio:
                buckets[-1].append(p)
            else:
                buckets.append([p])
        if len(buckets) > 1:
            out: Dict[tuple, Dict] = {}
            for bucket in buckets:
                out.update(run_grid(programs, static_cfg, bucket,
                                    mechanisms, seeds, dedup=dedup))
            # restore the caller's grid-point order
            return {k: out[k] for k in keys}

    squeeze_seed = seeds is None
    seed_arr = jnp.asarray(SIM.seed_i32(
        [static_cfg.seed] if seeds is None else list(seeds)))
    stacked, p_logical = _stack_programs(progs)
    W, G = len(progs), len(points)

    sims = [dataclasses.replace(static_cfg, **p) for p in points]
    n_ep_max = max(s.n_epochs for s in sims)
    # the ladder length is the one *static* field a power regime carries:
    # it sets shapes, so a grid may sweep regimes but not n_freqs
    pstats = {s.power.static_part() for s in sims}
    assert len(pstats) == 1, \
        f"power grid values must share one ladder length, got {pstats}"
    st = sims[0].static_part(n_epochs=n_ep_max)
    # never shard wider than the flat axis: a 1-point manager report on an
    # 8-device host would otherwise pad one row to 8 identical scans
    n_dev = min(jax.local_device_count(), W * G)
    full_ops = _flat_operands(stacked, p_logical, sims, n_dev)

    def classes_of(spec: MechanismSpec):
        """Grid-point equivalence classes of one spec's live axes."""
        if not dedup:
            return list(range(G)), sims
        return _exec_classes(sims, spec.dedup_axes)

    ops_cache: Dict[tuple, tuple] = {}

    def class_operands(class_of, class_sims):
        """(operands, n_dev) for a partition — the shared full-grid
        operands when it is trivial (so the common no-dead-axis case
        dispatches exactly the full-grid executable), memoized per
        partition so specs sharing one (all three statics, say) build the
        flattened arrays once."""
        if len(class_sims) == G:
            return full_ops, n_dev
        key = tuple(class_of)
        if key not in ops_cache:
            dev = min(jax.local_device_count(), W * len(class_sims))
            ops_cache[key] = (_flat_operands(stacked, p_logical, class_sims,
                                             dev), dev)
        return ops_cache[key]

    # per-mechanism result row-lookup: name -> (arrays, class_of, n_classes)
    by_mech: Dict[str, Tuple[Dict[str, jnp.ndarray], List[int], int]] = {}
    no_ids = jnp.zeros((0,), jnp.int32)  # specialized mechs ignore mech_ids

    # Traced fork-family mechanisms share executables; group them by the
    # *partition* their live axes induce on this grid (not by the axes
    # themselves), so mechanisms that agree on which points are equivalent
    # ride one dispatch. On a grid with no dead axes every traced spec
    # induces the identity partition and the whole family is ONE dispatch
    # over the full operands — bitwise-identical to the pre-spec dispatch;
    # a table_ema-only axis collapses the reactive (table-free) group to
    # one class per point set while PC mechanisms still span every point.
    groups: Dict[tuple, List[MechanismSpec]] = {}
    group_classes: Dict[tuple, Tuple[List[int], List[SimConfig]]] = {}
    for s in specs:
        if s.is_traced:
            class_of, class_sims = classes_of(s)
            gk = tuple(class_of)
            groups.setdefault(gk, []).append(s)
            group_classes[gk] = (class_of, class_sims)
    for gk, group in groups.items():
        class_of, class_sims = group_classes[gk]
        ops, dev = class_operands(class_of, class_sims)
        ids = jnp.asarray([SIM.FORK_MECH_IDS[s.name] for s in group],
                          jnp.int32)
        ys = _run_family(st, dev, None, ops, seed_arr, ids)
        for j, s in enumerate(group):
            by_mech[s.name] = ({k: v[:, :, j] for k, v in ys.items()},
                               class_of, len(class_sims))

    # Specialized families — static frequencies, oracle, and registered
    # custom mechanisms — compile their own executable and dedup the same
    # generic way (a static mech ignores objective AND table_ema; oracle
    # ignores table_ema).
    for s in specs:
        if s.is_traced:
            continue
        class_of, class_sims = classes_of(s)
        ops, dev = class_operands(class_of, class_sims)
        ys = _run_family(st, dev, s, ops, seed_arr, no_ids)
        by_mech[s.name] = (ys, class_of, len(class_sims))

    out: Dict[tuple, Dict[str, Dict[str, Dict[str, np.ndarray]]]] = {}
    for g, (key, sim_pt) in enumerate(zip(keys, sims)):
        out[key] = {}
        for w, name in enumerate(names_w):
            trs = {}
            for s in specs:
                arrs, class_of, C = by_mech[s.name]
                trs[s.name] = _unpack_trace(arrs, w * C + class_of[g], s,
                                            squeeze_seed,
                                            n_ep=sim_pt.n_epochs)
            out[key][name] = trs
    return out


# ---------------------------------------------------------------------------
# GridExecutor — the long-lived compiled-family handle for request streams
# ---------------------------------------------------------------------------


class PendingGrid:
    """The in-flight result of one :class:`GridExecutor` micro-batch.

    Dispatch is asynchronous: this object holds the executables' device
    arrays plus the row bookkeeping to cut them back into per-job
    ``run_sim``-schema traces, and nothing here synchronizes with the
    device until ``block_until_ready``/``traces`` is called — the caller
    can keep preparing and dispatching later batches while this one
    computes."""

    def __init__(self, rows, n_jobs: int):
        # rows: per job, {mech_name: (arrays, flat_row, spec, n_ep)}
        self._rows = rows
        self.n_jobs = n_jobs

    def block_until_ready(self) -> "PendingGrid":
        for job in self._rows:
            for arrs, _, _, _ in job.values():
                jax.block_until_ready(arrs)
        return self

    def traces(self) -> List[Dict[str, Dict[str, np.ndarray]]]:
        """Per-job ``{mechanism: trace}`` results (np arrays; blocks)."""
        return [{m: _unpack_trace(arrs, i, spec, True, n_ep)
                 for m, (arrs, i, spec, n_ep) in job.items()}
                for job in self._rows]


class GridExecutor:
    """A reusable handle on the compiled grid-executable family: the
    object a long-lived DVFS service holds between requests.

    ``run_grid`` lays out its operands per call from a (workloads x
    grid-points) product; a service consuming a *stream* of (job,
    telemetry) requests instead wants one static configuration compiled
    once and then fed micro-batches forever. A GridExecutor pins the
    static half — the ``SimStatic`` (shapes, flags, ladder length), the
    padded program block count ``p_max``, the mechanism set and the seed —
    plus a small set of static micro-batch shapes (``buckets``).
    ``dispatch`` pads each job list to the smallest admitting bucket by
    cycling jobs (the same move as ``run_grid``'s device-multiple
    padding; pad rows are dropped on unpack) and rides the SAME
    ``_grid_exec`` executables every ``run_grid`` call uses, so streamed
    rows are bitwise-equal to the one-shot grid answer for the same jobs
    and a whole request stream compiles at most one executable per
    (bucket shape x family) — with a single service bucket the fork
    family compiles ONCE for the life of the process, exactly the
    ``run_grid`` no-retrace contract carried over to streaming.

    ``buckets=None`` dispatches each batch at its exact size (one shape
    per distinct batch length — the mode for fixed-shape clients like the
    DVFS manager, whose repeated reports always arrive at the same batch
    size and therefore share ``run_grid``'s own executables); a tuple of
    sizes is the streaming mode. Dispatch is async — the returned
    :class:`PendingGrid` does not synchronize — and every dispatch builds
    its families' initial carries through the jit-cached per-``SimStatic``
    ``_carry_builder`` pool and donates them, so a depth-2 service
    pipeline keeps two carry generations alive: batch N+1's carry build
    and host->device transfer overlap batch N's compute."""

    def __init__(self, static_cfg: SimConfig,
                 mechanisms: Sequence[Union[str, MechanismSpec]] = MECHANISMS,
                 *, p_max: int = 1024,
                 buckets: Optional[Sequence[int]] = None,
                 n_dev: Optional[int] = None):
        self.static_cfg = static_cfg
        self.specs = [MECH.resolve(m) for m in mechanisms]
        assert self.specs, "GridExecutor needs at least one mechanism"
        self.p_max = p_max
        self.buckets = None if buckets is None else tuple(sorted(buckets))
        assert self.buckets is None or all(b >= 1 for b in self.buckets)
        self.n_dev = jax.local_device_count() if n_dev is None else n_dev
        self._st = static_cfg.static_part()
        self._seed_arr = jnp.asarray(SIM.seed_i32([static_cfg.seed]))
        self._traced = [s for s in self.specs if s.is_traced]
        self._special = [s for s in self.specs if not s.is_traced]
        self._fork_ids = jnp.asarray(
            [SIM.FORK_MECH_IDS[s.name] for s in self._traced], jnp.int32)
        self._no_ids = jnp.zeros((0,), jnp.int32)

    @property
    def max_batch(self) -> Optional[int]:
        """Largest micro-batch one dispatch admits (None = unbounded)."""
        return None if self.buckets is None else self.buckets[-1]

    def _bucket(self, n: int) -> int:
        if self.buckets is None:
            return n
        for b in self.buckets:
            if b >= n:
                return b
        raise AssertionError(
            f"micro-batch of {n} jobs exceeds the largest static shape "
            f"bucket {self.buckets[-1]} — split the batch or widen buckets")

    def dispatch(self, jobs: Sequence[Tuple[Program, dict]]) -> PendingGrid:
        """Dispatch one micro-batch of ``(Program, axes_overrides)`` jobs.

        Each job is one flat row of the grid executable: its program
        (padded to ``p_max`` blocks — semantics preserved, see
        ``pad_program``) and its own traced ``SimAxes`` point built from
        the executor's static config plus the per-job overrides (any
        ``AXIS_FIELDS`` subset; a job's logical ``n_epochs`` may not
        exceed the executor's static scan length). Asynchronous: returns
        a :class:`PendingGrid` immediately."""
        n = len(jobs)
        assert n >= 1, "dispatch needs at least one job"
        # Floor the bucket at 2 rows: a 1-row flat dispatch lets XLA fuse
        # the degenerate leading axis and codegen f32 chains at a shifted
        # last ulp vs the >=2-row shapes run_grid dispatches, breaking the
        # bitwise streamed-vs-one-shot contract for batch-1 requests. The
        # pad row is a cycled copy dropped on unpack, and ``ops[3]`` below
        # stays the logical ``n`` so DISPATCH_ROWS accounting is unchanged.
        bucket = max(self._bucket(n), 2)
        padded = [jobs[i % n] for i in range(bucket)]
        sims = []
        for prog, ov in padded:
            for k in ov:
                assert k in AXIS_FIELDS, \
                    f"{k!r} is not a traced grid axis (one of {AXIS_FIELDS})"
            s = dataclasses.replace(self.static_cfg, **dict(ov))
            assert s.n_epochs <= self._st.n_epochs, \
                f"job n_epochs {s.n_epochs} exceeds the executor's static " \
                f"scan length {self._st.n_epochs}"
            assert s.static_part(n_epochs=self._st.n_epochs) == self._st, \
                "job overrides must not change the executor's static half " \
                f"(got {s.static_part(n_epochs=self._st.n_epochs)})"
            assert prog.n_blocks <= self.p_max, \
                f"program {prog.name!r} has {prog.n_blocks} blocks > " \
                f"executor p_max {self.p_max}"
            sims.append(s)

        axes_flat = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[s.axes() for s in sims])
        p_log = jnp.asarray([p.n_blocks for p, _ in padded], jnp.int32)
        pp = [pad_program(p, self.p_max) for p, _ in padded]
        stacked = Program(
            "suite",
            *(jnp.stack([getattr(p, f) for p in pp])
              for f in ("i0_rate", "sens_rate", "mem_frac", "cum3")))
        n_dev = min(self.n_dev, bucket)
        n_pad = -(-bucket // n_dev) * n_dev
        if n_pad != bucket:
            stacked = _pad_flat(stacked, n_pad)
            p_log = _pad_flat(p_log, n_pad)
            axes_flat = _pad_flat(axes_flat, n_pad)
        # stage operands on device explicitly and asynchronously: under a
        # depth-2 service pipeline this host->device transfer (and the
        # donated carry build inside _run_family) overlaps the previous
        # batch's compute instead of queueing behind it at call time
        stacked, p_log, axes_flat = jax.device_put(
            (stacked, p_log, axes_flat))
        ops = (stacked, p_log, axes_flat, n)

        by_mech: Dict[str, Dict[str, jnp.ndarray]] = {}
        if self._traced:
            ys = _run_family(self._st, n_dev, None, ops, self._seed_arr,
                             self._fork_ids)
            for j, s in enumerate(self._traced):
                by_mech[s.name] = {k: v[:, :, j] for k, v in ys.items()}
        for s in self._special:
            by_mech[s.name] = _run_family(self._st, n_dev, s, ops,
                                          self._seed_arr, self._no_ids)

        rows = [{s.name: (by_mech[s.name], j, s, sims[j].n_epochs)
                 for s in self.specs} for j in range(n)]
        return PendingGrid(rows, n)

    def run(self, jobs: Sequence[Tuple[Program, dict]]
            ) -> List[Dict[str, Dict[str, np.ndarray]]]:
        """Synchronous convenience: ``dispatch`` + unpack."""
        return self.dispatch(jobs).traces()


def suite_metrics(programs: Union[Dict[str, Program], Sequence[Program]],
                  sim: SimConfig,
                  mechanisms: Sequence[Union[str, MechanismSpec]] = MECHANISMS,
                  n: int = 2,
                  traces: Optional[Dict] = None,
                  baseline: Union[str, MechanismSpec] = "static17"
                  ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Batched counterpart of ``run_workload`` over a whole suite: ED^nP
    per workload, normalized to ``baseline`` (any registered mechanism;
    default the paper's static 1.7 GHz). Pass ``traces`` (a ``run_suite``
    result that includes the baseline) to reuse already-computed traces."""
    mech_specs = [MECH.resolve(m) for m in mechanisms]
    base_spec = MECH.resolve(baseline)
    if traces is None:
        need = tuple(mechanisms)
        if all(s.name != base_spec.name for s in mech_specs):
            need = (base_spec,) + need
        traces = run_suite(programs, sim, need)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for name, trs in traces.items():
        base = trs[base_spec.name]
        budget = 0.9 * base["work"].sum()
        E0, D0, M0 = ednp(base, budget, sim.epoch_us, n)
        out[name] = {}
        for s in mech_specs:
            E, D, M = ednp(trs[s.name], budget, sim.epoch_us, n)
            out[name][s.name] = {
                "accuracy": prediction_accuracy(trs[s.name])
                if s.family != "static" else float("nan"),
                "E": E, "D": D, "ednp": M, "ednp_norm": M / M0,
                "energy_norm": E / E0, "delay_norm": D / D0,
            }
    return out
