"""Wavefront-program workload models.

A *program* is a looped sequence of P instruction blocks (4 instructions per
block — the paper's 4-bit PC offset granularity). Block j has:

  i0_rate[j]   instructions/us committed independent of f (async/memory part)
  sens_rate[j] instructions/us/GHz committed proportional to f (core part)
  mem_frac[j]  fraction of traffic that hits the shared L2/DRAM path

so a wavefront sitting in block j commits ``(i0 + sens*f) * T`` instructions
per epoch (the paper's linear model I_f = I0 + S*f, Fig 5, R^2=0.82).

Programs are generated as piecewise-constant *phase segments* (compute,
memory, balanced) whose lengths/levels are drawn per workload kind; this
reproduces the paper's observed behaviors: 37% consecutive-epoch sensitivity
variation at 1us shrinking at coarser epochs (Fig 7), ~10% same-PC iteration
variation (Fig 10), and per-workload phenomenology of Table II (dgemm-like
heterogeneous compute, xsbench-like memory-bound, BwdPool constant-rate,
FwdSoft L2-thrash coupling, ...).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INSTR_PER_BLOCK = 4


@dataclass
class Program:
    name: str
    i0_rate: jnp.ndarray    # (P,) instr/us
    sens_rate: jnp.ndarray  # (P,) instr/us/GHz
    mem_frac: jnp.ndarray   # (P,)
    # prefix sums over a doubled program for O(1) wrapped window averages,
    # packed as (2P+1, 3) columns (i0, sens, mem): the scan-invariant of
    # the engine's window gather (12 contiguous bytes/index), precomputed
    # here so the per-epoch scan body never re-materializes the stack —
    # and the ONLY prefix-sum leaf, so batched sweeps don't ship three
    # redundant unpacked copies through every executable
    cum3: jnp.ndarray

    @property
    def n_blocks(self) -> int:
        return self.i0_rate.shape[0]

    # column views for analyses/tests that want one prefix sum
    @property
    def cum_i0(self) -> jnp.ndarray:
        return self.cum3[:, 0]

    @property
    def cum_sens(self) -> jnp.ndarray:
        return self.cum3[:, 1]

    @property
    def cum_mem(self) -> jnp.ndarray:
        return self.cum3[:, 2]


# Register Program as a pytree so it can flow through jit/vmap/scan — the
# batched sweep layer (repro.core.sweep) vmaps run_sim across stacked
# Programs, and the jit-cached run_sim entry point takes Program as a traced
# argument. The name is deliberately NOT aux data: jit cache keys include
# the treedef, so a name in the aux would force a re-trace per workload and
# defeat the shape-keyed executable cache. Programs reconstructed inside a
# transform therefore carry an empty name (nothing traced reads it).
jax.tree_util.register_pytree_node(
    Program,
    lambda p: ((p.i0_rate, p.sens_rate, p.mem_frac, p.cum3), None),
    lambda _, ch: Program("", *ch),
)


def _finalize(name, i0, sens, mem) -> Program:
    i0 = jnp.asarray(i0, jnp.float32)
    sens = jnp.asarray(sens, jnp.float32)
    mem = jnp.asarray(mem, jnp.float32)
    cum = lambda a: jnp.concatenate([jnp.zeros(1), jnp.cumsum(jnp.tile(a, 2))])
    return Program(name, i0, sens, mem,
                   jnp.stack([cum(i0), cum(sens), cum(mem)], axis=-1))


# base per-WF rate scale: a wavefront at 1.7 GHz commits ~100 instr/us
_RATE = 100.0


def _segments(rng: np.random.Generator, P: int, palettes,
              seg_len_mean: float, hetero: float = 0.3):
    """Build piecewise-constant arrays. ``palettes`` is a list of phase
    palettes cycled deterministically (so phased workloads really alternate);
    the phase *within* a palette and the segment length are random."""
    if palettes and isinstance(palettes[0], tuple) and isinstance(palettes[0][0], float):
        palettes = [palettes]  # single palette
    i0 = np.zeros(P)
    sens = np.zeros(P)
    mem = np.zeros(P)
    pos, pi = 0, 0
    while pos < P:
        ln = max(2, int(rng.exponential(seg_len_mean)))
        kinds = palettes[pi % len(palettes)]
        pi += 1
        core_share, rate_mult, mfrac = kinds[rng.integers(len(kinds))]
        jitter = 1.0 + hetero * rng.standard_normal()
        rate = _RATE * rate_mult * max(jitter, 0.3)
        # at f=1.7: rate = i0 + sens*1.7 with core share of the f-scaling part
        sens_v = core_share * rate / 1.7
        i0_v = (1 - core_share) * rate
        i0[pos:pos + ln] = i0_v
        sens[pos:pos + ln] = sens_v
        mem[pos:pos + ln] = mfrac
        pos += ln
    return i0, sens, mem


# phase palettes: (core_share, rate_mult, mem_frac)
_COMPUTE = [(0.9, 1.4, 0.05), (0.8, 0.7, 0.1), (0.95, 1.1, 0.02), (0.85, 1.8, 0.08),
            (0.45, 0.9, 0.45)]  # tile prologue/epilogue interludes
_MEMORY = [(0.15, 0.7, 0.8), (0.25, 0.8, 0.7), (0.1, 0.6, 0.9)]
_BALANCED = [(0.55, 1.0, 0.35), (0.45, 0.9, 0.45)]
_ALL = _COMPUTE + _MEMORY + _BALANCED


# (generator spec, mem_frac acceptance band) per kind — rejection sampling
# guarantees every generated program really has its intended phase mix.
_KIND_SPECS = {
    "compute":  (([_COMPUTE, _COMPUTE, _BALANCED], 32, 0.7), (0.0, 0.3)),
    "memory":   (([_MEMORY, _MEMORY, _MEMORY, _BALANCED], 32, 0.4), (0.5, 1.0)),
    "phased":   (([_COMPUTE, _MEMORY], 36, 0.5), (0.25, 0.55)),
    "irregular": (([_ALL], 12, 0.8), (0.15, 0.6)),
    "constant": (([(0.5, 1.0, 0.3)], 100_000, 0.0), (0.0, 1.0)),
    "thrash":   (([(0.7, 1.2, 0.75), (0.6, 1.1, 0.8)], 40, 0.3), (0.5, 1.0)),
    "mixed":    (([_BALANCED, _COMPUTE, _MEMORY], 24, 0.5), (0.15, 0.45)),
}


def make_program(name: str, kind: str, seed: int, P: int = 1024) -> Program:
    (palettes, seg_len, hetero), (lo, hi) = _KIND_SPECS[kind]
    for trial in range(50):
        rng = np.random.default_rng(seed + 1000 * trial)
        i0, s, m = _segments(rng, P, palettes, seg_len_mean=min(seg_len, P),
                             hetero=hetero)
        if lo <= float(np.mean(m)) <= hi:
            break
    return _finalize(name, i0, s, m)


# The paper's workload suite (Table II), mapped to generator kinds.
WORKLOAD_TABLE: Dict[str, Tuple[str, int]] = {
    # HPC apps
    "comd": ("phased", 11),
    "hpgmg": ("memory", 12),
    "lulesh": ("irregular", 13),
    "minife": ("mixed", 14),
    "xsbench": ("memory", 15),
    "hacc": ("phased", 16),
    "quickS": ("irregular", 17),
    "pennant": ("mixed", 18),
    "snapc": ("memory", 19),
    # MI apps
    "dgemm": ("compute", 21),
    "BwdBN": ("mixed", 22),
    "BwdPool": ("constant", 23),
    "BwdSoft": ("memory", 24),
    "FwdBN": ("mixed", 25),
    "FwdPool": ("constant", 26),
    "FwdSoft": ("thrash", 27),
}


def get_workload(name: str, P: int = 1024) -> Program:
    kind, seed = WORKLOAD_TABLE[name]
    return make_program(name, kind, seed, P=P)


def all_workloads(P: int = 1024) -> Dict[str, Program]:
    return {n: get_workload(n, P) for n in WORKLOAD_TABLE}
