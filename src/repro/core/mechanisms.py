"""Mechanism-as-data: the declarative ``MechanismSpec`` registry.

The paper's contribution is a *family* of DVFS mechanisms — three static
frequencies, five reactive estimators (STALL/LEAD/CRIT/CRISP plus the
fork-derived ACCREAC), two PC-table predictors (PCSTALL/ACCPC) and the
fork oracle. This module is the single source of truth for that family:
each mechanism is one frozen :class:`MechanismSpec` value, and the engine
(``repro.core.simulate``), the sweep layer (``repro.core.sweep``), the
DVFS runtime manager, figures and benchmarks all *derive* their dispatch
structure from the registry instead of hardcoding name tuples and magic
ids.

What a spec declares
--------------------
``family``
    One of :data:`FAMILIES`. ``static`` mechanisms pin one V/f index and
    never predict; ``reactive`` mechanisms predict from CU-level linear
    state; ``pc`` mechanisms predict from the PC-indexed table; ``oracle``
    predicts from this epoch's own forks (and therefore cannot ride the
    fused 11-way execute).
``traced_id``
    The mechanism's stable integer id in the traced fork-family scan.
    These ids are **part of the bitwise contract**: the batched sweep
    layer vmaps one compiled executable over them, and the scan body's
    branch selection compares against them — renumbering would change
    compiled graphs and invalidate captured reference traces
    (``tests/data/grid_reference.npz``). Builtin ids are frozen at
    registration; user-registered mechanisms never get one (they dispatch
    as their own specialized executable, like oracle).
``exec_axes``
    The :data:`SIM_AXES_FIELDS` the mechanism's trace actually depends
    on. Everything else is a *dead input* to its executable, which is
    what the sweep layer's generic deduplication exploits: grid points
    agreeing on a spec's live axes form one equivalence class and share
    one scan, with the result broadcast to every member grid key. A
    static frequency ignores the objective and the table EMA; a reactive
    (table-free) mechanism ignores the table EMA; PC mechanisms consume
    everything. The ``power`` axis (the traced IVR regime) is live for
    every family — static frequencies included — because the V/f ladder
    and the energy accounting read it unconditionally.
``predict`` / ``update``
    Optional hooks that make the family user-extensible *without touching
    the engine*: a registered mechanism with a ``predict`` hook runs
    through the same fused fork--pre-execute scan as the builtin
    mechanisms, its hook supplying the ``(CU, 10)`` next-epoch
    instruction prediction (see `Hook contract`_ below).

Hook contract
-------------
``predict(carry, ctx, st, ax) -> (n_cu, n_freqs) array``
    Predicted instructions committed next epoch at every V/f state of
    ``repro.core.power.FREQS_GHZ``. ``carry`` is the scan state
    (``simulate.Carry``: per-CU reactive rates ``react_i0``/``react_sens``
    in instr/us(/GHz), the PC table, per-WF fallbacks), ``ctx`` the
    frequency-independent epoch context (``simulate.EpochCtx``: starting
    blocks and the program's local ``i0_l``/``s_l`` code rates), ``st``/
    ``ax`` the static config and traced grid point. Use
    ``simulate.predict_instr(i0_cu, sens_cu, st, ax)`` to lower a per-CU
    linear model to the capacity-clipped prediction the controller
    expects.
``update(counters, f_sel, I_f, carry, ctx, st, ax) -> (i0, sens) | None``
    Digest this epoch's hardware counters (estimator view: ``committed``
    is the steady-state counter) plus the fork results ``I_f``
    (``(CU, 10)`` committed instructions per uniform V/f row) into new
    per-CU reactive state, in instr/us(/GHz) *rate* units; ``None``
    leaves the carry unchanged.

Both hooks are traced by JAX inside the scan body: they must be pure
jax-traceable functions of their operands. A custom ``family='pc'`` spec
additionally gets the standard PC-table machinery maintained around its
hooks — counter-driven table updates and lookup hit telemetry (the
``hit_rate`` channel, surfaced by ``hit_telemetry=True``) — so its
``predict`` can read a live ``carry.table`` without reimplementing the
estimator plumbing.

Parameterized hooks
-------------------
Compiled executables are keyed on the spec *value* and plain hook
functions compare by identity, which leaves a predictor parameterized by
weights (a learned model, a tunable blend) two bad options: rebind a
fresh closure per weight set (new identity — a fresh executable family
per registration even for bit-identical weights) or mutate a shared
closure cell (the cached executable keeps the OLD weights baked in as
trace constants — silently stale results). :class:`ParamHook` is the
supported contract for this case: it binds a stable module-level hook
function to a ``{name: array}`` parameter dict and compares/hashes by
``(function identity, parameter shape/dtype/bytes)``. Equal-valued
parameters hit every spec-keyed cache; any changed byte makes an unequal
spec and compiles a fresh specialized family; and neither case can
perturb the shared builtin fork family, whose executables key on no
custom spec at all (regression-tested in ``tests/test_learn.py``).

The registry
------------
:func:`register` validates and adds a spec (duplicate names error unless
``allow_override=True``); :func:`resolve` accepts a name or a spec
uniformly and is what every dispatch path calls; :func:`specs` /
:func:`names` enumerate; :func:`mechanism_table` renders the registry as
the markdown table embedded in the README (``python -m
repro.core.mechanisms`` prints it). ``BUILTIN_NAMES`` is the frozen
paper set (the default mechanism suite of ``run_suite``/``run_grid``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core import power as PWR

# The traced SimAxes fields, declared here (the registry is the dependency
# root) and asserted against simulate.SimAxes._fields at engine import so
# the two can never drift. ``power`` is the nested PowerAxes pytree — one
# traced IVR/hardware regime (V/f endpoints, leakage, IVR efficiency,
# transition model), sweepable like any scalar axis.
SIM_AXES_FIELDS = ("epoch_us", "sigma", "cap_per_ghz", "membw", "table_ema",
                   "obj", "n_ep", "power")

# SimAxes field -> SimConfig field, for the sweep layer's equivalence-class
# keys (the grid API speaks SimConfig names).
AXIS_TO_CONFIG = {"obj": "objective", "n_ep": "n_epochs"}

FAMILIES = ("static", "reactive", "pc", "oracle")

# the DEFAULT ladder length; a grid may sweep PowerConfig regimes with a
# different (but grid-constant) n_freqs — static V/f indices are validated
# against the actual ladder at dispatch
N_FREQS = PWR.DEFAULT.n_freqs

# Engine-imposed live axes: the scan unconditionally reads these for every
# mechanism (execution model + logical-epoch mask + the power regime: the
# V/f ladder, the energy accounting and the transition model read
# ``power`` even for a static frequency — unlike objective/table_ema, a
# swept power axis is live for EVERYONE and never collapses in the grid
# dedup), plus the objective for anything that selects a frequency and
# the table EMA for anything the engine maintains a PC table for.
# exec_axes may declare MORE liveness (costing only dedup opportunity)
# but never less — an omitted live axis would make the sweep layer
# broadcast wrong results.
_REQUIRED_AXES = ("epoch_us", "sigma", "cap_per_ghz", "membw", "n_ep",
                  "power")


@dataclass(frozen=True)
class MechanismSpec:
    """One DVFS mechanism, as data. Frozen and hashable: specs are jit
    static arguments of the engine's cached executables."""
    name: str
    family: str                              # one of FAMILIES
    exec_axes: Tuple[str, ...]               # live SIM_AXES_FIELDS
    label: str = ""                          # plot/report label
    color: Optional[str] = None              # plot metadata
    static_fidx: Optional[int] = None        # family='static': V/f index
    traced_id: Optional[int] = None          # fork-family scan id (builtin)
    cu_model: Optional[str] = None           # reactive estimator name
    fork_estimator: bool = False             # estimate from fork rows (acc*)
    hit_telemetry: bool = False              # emits the hit_rate channel
    predict: Optional[Callable] = None       # custom predictor hook
    update: Optional[Callable] = None        # custom estimator hook
    # Documented waiver for a FALSE under-declaration reported by the
    # axis-liveness auditor (repro.analysis.deps): the conservative jaxpr
    # walk can over-approximate through exotic primitives. Setting this
    # downgrades the auditor's hard error to a warning carrying this
    # text. Never use it to silence a REAL under-declaration — that is
    # exactly the dedup-unsoundness the auditor exists to prevent.
    liveness_waiver: Optional[str] = None
    # Whether the fused v2 epoch kernel (kernels.epoch_fused) can serve
    # this mechanism's scan. Forced False in __post_init__ for families
    # the kernel does not model: static pins (no predict step), the fork
    # oracle (reads this epoch's own forks), and custom predict hooks
    # (arbitrary traced callables). Under ``use_pallas`` v2 such specs
    # silently fall back to the jnp scan body — same numerics contract
    # as the default path.
    v2_capable: bool = True

    def __post_init__(self):
        assert self.family in FAMILIES, \
            f"family {self.family!r} not in {FAMILIES}"
        bad = [a for a in self.exec_axes if a not in SIM_AXES_FIELDS]
        assert not bad, \
            f"exec_axes {bad} not SimAxes fields (one of {SIM_AXES_FIELDS})"
        assert len(set(self.exec_axes)) == len(self.exec_axes), \
            f"duplicate exec_axes in {self.exec_axes}"
        # canonicalize to SimAxes field order so equal axis *sets* compare
        # and hash equal regardless of declaration order
        canon = tuple(a for a in SIM_AXES_FIELDS if a in self.exec_axes)
        object.__setattr__(self, "exec_axes", canon)
        if self.family == "static":
            assert self.static_fidx is not None and \
                0 <= self.static_fidx < N_FREQS, \
                f"static mechanism needs static_fidx in [0, {N_FREQS})"
            assert self.predict is None and self.update is None, \
                "static mechanisms take no predictor hooks"
        else:
            assert self.static_fidx is None, \
                f"{self.family} mechanism must not set static_fidx"
        if self.update is not None:
            assert self.predict is not None, \
                "an update hook requires a predict hook"
        # hook requirements hold by construction (not just at register
        # time): without them an unregistered custom-looking spec would
        # silently trace a builtin predictor path instead of its own
        if self.family in ("reactive", "pc") and self.predict is None \
                and self.traced_id is None:
            raise ValueError(
                f"custom {self.family} mechanism {self.name!r} needs a "
                "predict hook (builtin predictor paths are traced-id "
                "dispatch only)")
        if self.hit_telemetry and self.family != "pc":
            raise ValueError(
                "hit_telemetry requires family='pc' — only the PC-table "
                "path emits the hit_rate channel")
        required = set(_REQUIRED_AXES)
        if self.family != "static":
            required.add("obj")         # _select_freq reads the objective
        if self.family == "pc":
            required.add("table_ema")   # table maintenance reads the EMA
        missing = [a for a in SIM_AXES_FIELDS
                   if a in required and a not in self.exec_axes]
        if missing:
            raise ValueError(
                f"{self.family} mechanism {self.name!r} must declare the "
                f"engine-imposed live axes {missing} in exec_axes — an "
                "omitted live axis makes the grid dedup broadcast wrong "
                "results")
        if self.family in ("static", "oracle") or self.predict is not None:
            object.__setattr__(self, "v2_capable", False)
        if not self.label:
            object.__setattr__(self, "label", self.name)

    @property
    def is_traced(self) -> bool:
        """True if the mechanism rides the shared traced-id fork
        executable (builtin non-oracle fork mechanisms)."""
        return (self.traced_id is not None and self.family != "oracle"
                and self.predict is None)

    @property
    def config_axes(self) -> Tuple[str, ...]:
        """``exec_axes`` mapped to SimConfig field names."""
        return tuple(AXIS_TO_CONFIG.get(a, a) for a in self.exec_axes)

    @property
    def dedup_axes(self) -> Tuple[str, ...]:
        """The SimConfig fields keying this spec's grid equivalence
        classes. ``n_epochs`` is excluded: the scan is causal, so a class
        representative runs to the class-max logical epoch count and every
        member slices its prefix (see ``sweep._exec_classes``)."""
        return tuple(a for a in self.config_axes if a != "n_epochs")


class ParamHook:
    """A predict/update hook parameterized by arrays, compared by VALUE.

    Binds a stable module-level hook function ``fn`` to a flat
    ``{name: array}`` parameter dict and calls it as
    ``fn(*hook_args, params=params)`` — the hook closes over the host
    numpy arrays, which JAX traces in as constants (frozen weights).

    Equality and hashing cover ``(fn identity, per-parameter name/shape/
    dtype/bytes)``, which is exactly the key the executable caches need:

    * re-creating a spec around equal-valued parameters (e.g. reloading
      the same frozen-weights artifact) compares equal, so every
      spec-keyed cache — ``sweep._grid_exec``, the dedup-audit cache,
      ``resolve`` — HITS and nothing retraces;
    * changing any parameter byte makes an unequal spec, so the value
      gets its OWN freshly-compiled specialized family and can never
      alias a stale executable with old weights baked in;
    * the shared builtin fork family keys on no custom spec either way,
      so weight swaps cannot retrace it.

    Parameters are defensively converted with ``np.asarray`` and keyed in
    sorted-name order; pass plain numpy (or nested-free jnp) arrays.
    """

    __slots__ = ("fn", "params", "_key", "_hash")

    def __init__(self, fn: Callable, params: Mapping[str, "np.ndarray"]):
        self.fn = fn
        self.params = {k: np.asarray(params[k]) for k in sorted(params)}
        self._key = (fn, tuple(
            (k, v.shape, v.dtype.str, v.tobytes())
            for k, v in self.params.items()))
        self._hash = hash(self._key)

    def __call__(self, *args, **kw):
        return self.fn(*args, params=self.params, **kw)

    def __eq__(self, other):
        return isinstance(other, ParamHook) and self._key == other._key

    def __hash__(self):
        return self._hash

    def __repr__(self):
        shapes = {k: v.shape for k, v in self.params.items()}
        name = getattr(self.fn, "__name__", repr(self.fn))
        return f"ParamHook({name}, {shapes})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, MechanismSpec] = {}
# The DVFS service registers/uses mechanisms from dispatch threads; all
# registry mutations take this lock (reads of individual entries are
# safe: dict get/set are atomic and specs are immutable values).
_REG_LOCK = threading.Lock()


def register(spec: MechanismSpec, *,
             allow_override: bool = False,
             verify_axes: Optional[bool] = None) -> MechanismSpec:
    """Add ``spec`` to the registry and return it.

    Duplicate names raise unless ``allow_override=True`` (builtins can
    never be overridden — their traced ids and numerics are contract).
    User-registered mechanisms cannot claim a traced id: the traced fork
    family is a closed, bitwise-frozen set; custom mechanisms dispatch as
    their own specialized executable (exactly like oracle does).

    ``verify_axes`` runs the axis-liveness auditor
    (:func:`repro.analysis.deps.verify_spec_axes`) on the spec before it
    enters the registry: the spec's scan is abstract-evaled at a tiny
    static shape (no compile, ~100–400 ms once per spec per process —
    the result is cached and shared with the ``run_grid`` dispatch
    guard) and its true axis dependencies are checked against the
    declared ``exec_axes``. Under-declaration — the dedup-unsound
    direction — raises :class:`repro.analysis.deps.AxisLivenessError`
    and the spec is NOT registered; over-declaration warns naming the
    dead axis. The default (``None``) audits exactly the specs whose
    declarations are *not* already covered by the test suite: customs
    (anything outside ``BUILTIN_NAMES``) are verified, builtins —
    asserted exact in ``tests/test_analysis.py`` — are not re-traced.

    Cache note: compiled executables are keyed on the spec value, and
    hook functions compare by identity — re-registering with freshly
    created lambdas makes a new jit entry per registration (the old
    executable stays cached for the process lifetime). In long-running
    processes reuse hook *functions* and pass varying parameters through
    carry state or SimAxes — or, for weights that are genuinely part of
    the mechanism's identity (learned predictors), wrap the hook in
    :class:`ParamHook`, which compares by parameter value so equal
    weights reuse the cached executable and changed weights compile
    their own."""
    if spec.name in _REGISTRY:
        if not allow_override or spec.name in BUILTIN_NAMES:
            raise ValueError(
                f"mechanism {spec.name!r} is already registered"
                + ("" if allow_override else
                   " (pass allow_override=True to replace)"))
    if spec.name not in BUILTIN_NAMES:
        assert spec.traced_id is None, \
            "traced ids are reserved for the builtin fork family " \
            "(they are part of the bitwise dispatch contract)"
        assert spec.family != "oracle", \
            "the oracle family is the builtin fork oracle"
    if verify_axes is None:
        verify_axes = spec.name not in BUILTIN_NAMES
    if verify_axes:
        # lazy: mechanisms is the dependency root (simulate imports it);
        # the auditor imports simulate to trace the scan body
        from repro.analysis.deps import verify_spec_axes
        verify_spec_axes(spec)  # raises AxisLivenessError: not registered
    with _REG_LOCK:
        if spec.name in _REGISTRY and (
                not allow_override or spec.name in BUILTIN_NAMES):
            raise ValueError(
                f"mechanism {spec.name!r} is already registered")
        _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> None:
    """Remove a user-registered mechanism (builtins are permanent)."""
    assert name not in BUILTIN_NAMES, f"cannot unregister builtin {name!r}"
    with _REG_LOCK:
        _REGISTRY.pop(name, None)


def get(name: str) -> MechanismSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown mechanism {name!r}; registered: {names()}") from None


def resolve(mech: Union[str, MechanismSpec]) -> MechanismSpec:
    """Accept a mechanism name or spec uniformly; names look up the
    registry, spec instances are validated by construction. A spec whose
    name is registered must BE the registered spec (field-equal): silently
    substituting the registry entry — or running a variant under a
    registered name — would attribute one mechanism's results to
    another."""
    if isinstance(mech, MechanismSpec):
        reg = _REGISTRY.get(mech.name)
        if reg is not None:
            if reg != mech:
                raise ValueError(
                    f"spec {mech.name!r} differs from the registered "
                    "mechanism of that name; register the variant under "
                    "its own name (or allow_override=True)")
            return reg
        assert mech.traced_id is None, \
            "traced ids are reserved for the registered builtin fork family"
        return mech
    return get(mech)


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def specs() -> Tuple[MechanismSpec, ...]:
    return tuple(_REGISTRY.values())


def fork_specs() -> Tuple[MechanismSpec, ...]:
    """Builtin fork--pre-execute mechanisms in traced-id order (the order
    IS the contract: the sweep layer's mech_ids index this tuple)."""
    forks = sorted((s for s in _REGISTRY.values() if s.traced_id is not None),
                   key=lambda s: s.traced_id)
    ids = [s.traced_id for s in forks]
    assert ids == list(range(len(forks))), \
        f"traced ids must be contiguous from 0, got {ids}"
    return tuple(forks)


def traced_reactive_count() -> int:
    """Number of traced reactive ids. They must be 0..n-1: the scan body's
    reactive/pc branch select is a single ``mech < n`` compare."""
    react = [s.traced_id for s in _REGISTRY.values()
             if s.is_traced and s.family == "reactive"]
    assert sorted(react) == list(range(len(react))), react
    return len(react)


# ---------------------------------------------------------------------------
# Builtin paper mechanisms
# ---------------------------------------------------------------------------

_EXEC = ("epoch_us", "sigma", "cap_per_ghz", "membw", "n_ep", "power")
_CTRL = _EXEC + ("obj",)          # + objective: drives frequency selection
_TABLE = _CTRL + ("table_ema",)   # + table EMA: drives the PC table

BUILTIN_NAMES = ("static13", "static17", "static22",
                 "stall", "lead", "crit", "crisp",
                 "accreac", "pcstall", "accpc", "oracle")

for _s in (
    MechanismSpec("static13", "static", _EXEC, static_fidx=0,
                  label="static 1.3 GHz"),
    MechanismSpec("static17", "static", _EXEC, static_fidx=4,
                  label="static 1.7 GHz"),
    MechanismSpec("static22", "static", _EXEC, static_fidx=9,
                  label="static 2.2 GHz"),
    MechanismSpec("stall", "reactive", _CTRL, traced_id=0, cu_model="stall",
                  label="STALL (reactive)"),
    MechanismSpec("lead", "reactive", _CTRL, traced_id=1, cu_model="lead",
                  label="LEAD (reactive)"),
    MechanismSpec("crit", "reactive", _CTRL, traced_id=2, cu_model="crit",
                  label="CRIT (reactive)"),
    MechanismSpec("crisp", "reactive", _CTRL, traced_id=3, cu_model="crisp",
                  label="CRISP (reactive)"),
    MechanismSpec("accreac", "reactive", _CTRL, traced_id=4,
                  fork_estimator=True, label="ACC-REAC (fork-accurate)"),
    MechanismSpec("pcstall", "pc", _TABLE, traced_id=5,
                  hit_telemetry=True, label="PCSTALL (predictive)"),
    MechanismSpec("accpc", "pc", _TABLE, traced_id=6, fork_estimator=True,
                  hit_telemetry=True, label="ACC-PC (fork-accurate table)"),
    MechanismSpec("oracle", "oracle", _CTRL, traced_id=7,
                  label="fork oracle"),
):
    # repro: waive[REPRO006] import-time builtin registration, no threads yet
    _REGISTRY[_s.name] = _s
del _s

assert names() == BUILTIN_NAMES


def mechanism_table(verify: bool = True) -> str:
    """The registry as a markdown table (embedded in the README).

    With ``verify=True`` (the default; ``python -m repro.core.mechanisms``
    uses it) each row's live-axes cell is stamped against the
    axis-liveness auditor: ``✓`` means the auditor derived *exactly* the
    declared set from the spec's jaxpr, ``~`` an over-declaration (a
    declared-but-dead axis), ``waived`` a documented auditor waiver —
    so the README table is evidence, not just a claim."""
    marks = {}
    if verify:
        from repro.analysis.deps import axis_liveness
        for s in specs():
            res = axis_liveness(s)
            if res.under_declared:
                marks[s.name] = "waived" if res.waiver else "✗ UNDER"
            else:
                marks[s.name] = "✓" if res.exact else "~ over"
    head = "| name | family | traced id | live axes | verified | label |" \
        if verify else "| name | family | traced id | live axes | label |"
    rows = [head, "|---|---|" + "---|" * (head.count("|") - 3)]
    for s in specs():
        tid = "—" if s.traced_id is None else str(s.traced_id)
        axes = ", ".join(a for a in s.exec_axes if a != "n_ep")
        cells = [f"`{s.name}`", s.family, tid, axes]
        if verify:
            cells.append(marks[s.name])
        cells.append(s.label)
        rows.append("| " + " | ".join(cells) + " |")
    return "\n".join(rows)


if __name__ == "__main__":
    # under `python -m` this file is the `__main__` module, a second
    # instance whose specs the canonical registry (which the auditor
    # imports) would not recognize — render via the canonical module
    from repro.core.mechanisms import mechanism_table as _table
    print(_table())
