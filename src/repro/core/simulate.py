"""Fine-grain DVFS simulation engine (paper §5 methodology, in JAX).

One ``lax.scan`` step = one fixed-time epoch (paper §3.1):

  1. *fork--pre-execute oracle* (paper Fig 13): the epoch is evaluated at all
     10 V/f states from bit-identical starting conditions — a functional
     simulator needs no process forking, and the per-epoch noise is keyed by
     (block, loop-iteration, wavefront) so forks see identical stochasticity,
     exactly like the paper's forked gem5 processes;
  2. the mechanism under test predicts next-epoch instructions I(f);
  3. the controller picks the per-domain frequency optimizing the objective;
  4. the epoch is (re-)executed with the chosen mixed per-CU frequencies;
  5. estimators digest the epoch's counters and update predictor state.

Ground-truth execution model: wavefront at PC block b commits
``(i0 + sens*f)*T`` instructions (window-averaged over the blocks traversed),
subject to (a) oldest-first issue-capacity contention within the CU
(Fig 11a) and (b) a shared L2/DRAM bandwidth cap across CUs (the FwdSoft
L2-thrash second-order effect, §6.2).

Batched execution model
-----------------------
The fork--pre-execute step and the real mixed-frequency execution share one
per-epoch *context* (``_epoch_context``): the PC-block gather, the loop
iteration index, and the deterministic noise hash are computed once per
epoch and reused by every frequency row. ``_execute_ctx`` then evaluates an
arbitrary ``(..., CU)`` batch of frequency vectors against that context, so
for every mechanism whose prediction does not depend on this epoch's forks
(everything except ``oracle``) the 10 uniform fork rows and the chosen
mixed-frequency row run as a single 11-way batched execute.

Caching contract
----------------
``SimConfig`` is split into two views for the jit boundary:

* ``SimStatic`` (``sim.static_part()``) — the shape/flag fields (CU/WF
  counts, scan length, table geometry, ``record_wf``, ``use_pallas``).
  This hashable frozen dataclass is the *only* config key of the cached
  executables.
* ``SimAxes`` (``sim.axes()``) — everything that can vary across a figure
  grid (``epoch_us``, ``sigma``, ``cap_per_ghz``, ``membw``, ``table_ema``,
  the objective lowered to a weight vector, the logical epoch count, and
  the ``power`` regime — a nested ``power.PowerAxes`` pytree carrying the
  V/f ladder endpoints, leakage/efficiency/capacitance constants and the
  IVR transition-latency model) as a traced pytree of scalars. The V/f
  ladder itself is built in-trace from the traced endpoints and the static
  ladder length (``PowerStatic.n_freqs``, nested in ``SimStatic``), so a
  whole IVR-regime sensitivity sweep rides one executable.

Mechanism dispatch contract
---------------------------
Mechanisms are *data*: every mechanism is a frozen ``MechanismSpec`` in
the ``repro.core.mechanisms`` registry, and this engine derives its whole
dispatch structure from the specs — the family branch taken by the scan
body (static / reactive / pc / oracle), the static V/f index, the traced
fork-family ids the branch selects compare against (frozen by the
registry: they are part of the bitwise contract, verified against
captured reference traces in ``tests/data``), and the predictor/estimator
hooks of user-registered mechanisms (which trace into their own
specialized executable without any engine edit — see
``mechanisms.register``).

``run_sim`` dispatches through a ``jax.jit`` entry point whose static keys
are ``SimStatic`` and the resolved ``MechanismSpec``; ``Program`` is a
registered pytree traced by shape only, and ``SimAxes`` rides along as a
traced operand. Repeated calls that differ only in axis values — a
fig-15/17/18 sweep over epoch granularities or objectives — therefore hit
the same executable and never re-trace. The scan body also accepts a
*traced* mechanism id (see ``FORK_MECHS``) so the batched sweep layer
(``repro.core.sweep``) can vmap one compiled executable across mechanisms,
workloads, seeds, *and* whole ``SimAxes`` grids (``run_grid``).

The objective is lowered from a string branch to a (3,) weight vector
``[pbar_weight, use_rate, cap_fraction]`` (see ``objective_weights``) so
EDP, ED^2P and the perf-cap objectives are a single traced code path.

``n_epochs`` couples to ``epoch_us`` in the paper's granularity sweeps, so
the scan always runs to the static ``SimStatic.n_epochs`` while
``SimAxes.n_ep`` carries the *logical* epoch count: epochs past it are
masked to zero in the outputs (the same pad-and-mask move the sweep layer
applies to programs of different block counts).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import estimators as EST
from repro.core import mechanisms as MECH
from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core.mechanisms import MechanismSpec
from repro.core.workloads import INSTR_PER_BLOCK, Program

# The mechanism family is DATA (repro.core.mechanisms): every dispatch
# structure below — name tuples, static frequency indices, traced fork ids,
# predictor-branch selection — derives from the MechanismSpec registry.
# The derived VALUES are part of the bitwise contract (captured reference
# traces in tests/data/): the registry freezes builtin traced ids, so the
# compiled graphs cannot drift.
MECHANISMS = MECH.BUILTIN_NAMES

# Mechanisms that run the fork--pre-execute step, in traced-id order: the
# batched sweep layer vmaps the scan over these integer ids (the carry is
# shape-identical across all of them). The traced path only accepts
# non-oracle ids (0..6): oracle predicts from this epoch's forks, which
# breaks the fused 11-way execute, so run_suite gives it its own
# specialized executable (user-registered mechanisms dispatch the same
# way — see mechanisms.register).
FORK_MECHS = tuple(s.name for s in MECH.fork_specs())
FORK_MECH_IDS = {m: i for i, m in enumerate(FORK_MECHS)}
# traced ids 0.._N_REACT-1 predict from CU-level reactive state (registry
# asserts contiguity: the branch select is a single `mech < n` compare)
_N_REACT = MECH.traced_reactive_count()
_REACT_SPECS = tuple(s for s in MECH.fork_specs()
                     if s.is_traced and s.family == "reactive")
_PC_IDS = tuple(s.traced_id for s in MECH.fork_specs()
                if s.is_traced and s.family == "pc")
# the one traced PC mechanism estimating from hardware counters (pcstall);
# the other (accpc) takes the exact per-WF linear model from the forks
_ID_CTR_PC = next(s.traced_id for s in MECH.fork_specs()
                  if s.is_traced and s.family == "pc"
                  and not s.fork_estimator)
# the traced scan body builds its reactive-estimator select in this order:
# counter models at ids 0..n-2, the fork-accurate reactive (accreac) last
assert all(s.cu_model for s in _REACT_SPECS[:-1]) and \
    _REACT_SPECS[-1].fork_estimator, _REACT_SPECS
# the shared traced-id executable can run the fused v2 epoch kernel only
# if EVERY mechanism it multiplexes is v2-capable (all builtin traced
# fork mechanisms are; the flag exists for the fallback contract of
# oracle/custom/static specs — see MechanismSpec.v2_capable)
_FORK_V2_CAPABLE = all(s.v2_capable for s in MECH.fork_specs()
                       if s.is_traced)


@dataclass(frozen=True)
class SimStatic:
    """Shape/flag half of ``SimConfig`` — the jit cache key. Everything
    here changes array shapes or trace structure; everything that doesn't
    lives in ``SimAxes`` and is traced. Construct via
    ``SimConfig.static_part()`` (all fields required: defaults live on
    ``SimConfig`` only, so they cannot drift)."""
    n_cu: int
    n_wf: int
    n_epochs: int                 # static scan length (max over a grid)
    entries: int
    offset_blocks: int
    cus_per_table: int
    cus_per_domain: int
    record_wf: bool
    # Pallas kernel generation: False (pure jnp), "v1" (fused PC-table
    # predict/update pair), "v2" (ONE fused fork--execute epoch kernel),
    # True = auto (v2 when the mechanism/flags permit, else v1, else jnp)
    use_pallas: Union[bool, str]
    # v2 only: tile the CU axis of the fused kernel over a
    # (n_cu // pallas_block_cu,)-grid pallas_call pair (None = monolithic)
    pallas_block_cu: Optional[int]
    power: PWR.PowerStatic        # ladder length (sets fork/predict shapes)


class SimAxes(NamedTuple):
    """Traced sweep axes: one grid point of the figure grid. All scalars
    (``obj`` is the (3,) lowered objective) so the sweep layer can stack
    grid points along a leading axis and vmap the scan over them."""
    epoch_us: jnp.ndarray     # () f32
    sigma: jnp.ndarray        # () f32
    cap_per_ghz: jnp.ndarray  # () f32
    membw: jnp.ndarray        # () f32
    table_ema: jnp.ndarray    # () f32
    obj: jnp.ndarray          # (3,) f32 [pbar_weight, use_rate, cap_frac]
    n_ep: jnp.ndarray         # () i32 logical epochs (<= SimStatic.n_epochs)
    power: PWR.PowerAxes      # nested traced IVR/hardware regime


# the registry declares the axis vocabulary MechanismSpec.exec_axes is
# validated against; it must be exactly the traced grid-point fields
assert SimAxes._fields == MECH.SIM_AXES_FIELDS, \
    (SimAxes._fields, MECH.SIM_AXES_FIELDS)


def objective_weights(objective: str) -> np.ndarray:
    """Lower an objective name to the traced weight vector
    ``[pbar_weight, use_rate, cap_frac]`` consumed by ``_select_freq``:

      cost = (P_dom + pbar_weight * Pbar) / where(use_rate, I_sum, 1)
             + BIG * (I_sum < cap_frac * I_sum[fmax])

    EDP/ED^2P set ``pbar_weight`` to the delay exponent n (the online
    Lagrangian marginal-cost weight) and divide by the rate; perf-cap
    objectives drop both and penalize infeasible frequencies instead.

    ``deadline<pct>`` is the deadline-aware energy objective in the
    Ilager et al. style (arXiv:2004.08177): minimize power — including
    the online average-power term, which keeps sustained draw low across
    phases — subject to holding at least ``1 - pct/100`` of the
    max-frequency rate (the per-epoch deadline slack). It differs from
    ``perfcap<pct>`` exactly by the ``pbar_weight`` term: perf-cap
    minimizes instantaneous power alone under the same feasibility
    penalty. New objectives lower here into the FIXED (3,) vector — the
    traced graph never changes, so they sweep through ``run_grid`` like
    any other ``objective`` axis value with zero dispatch edits."""
    if objective == "edp":
        return np.asarray([1.0, 1.0, 0.0], np.float32)
    if objective == "ed2p":
        return np.asarray([2.0, 1.0, 0.0], np.float32)
    if objective.startswith("perfcap"):
        capf = 1.0 - float(objective[-2:]) / 100.0
        return np.asarray([0.0, 0.0, capf], np.float32)
    if objective.startswith("deadline"):
        pct = objective[len("deadline"):]
        if len(pct) != 2 or not pct.isdigit():
            raise ValueError(objective)
        capf = 1.0 - float(pct) / 100.0
        return np.asarray([1.0, 0.0, capf], np.float32)
    raise ValueError(objective)


@dataclass(frozen=True)
class SimConfig:
    n_cu: int = 64
    n_wf: int = 40
    epoch_us: float = 1.0
    n_epochs: int = 1500
    entries: int = 128
    offset_blocks: int = 8        # blocks/entry: 128 entries cover a 1024-block loop
    cus_per_table: int = 1
    cus_per_domain: int = 1
    objective: str = "ed2p"       # 'edp'|'ed2p'|'perfcap<pct>'|'deadline<pct>'
    sigma: float = 0.06           # same-PC iteration noise (Fig 10 ~10%)
    cap_per_ghz: float = 5500.0   # CU issue capacity, instr/us per GHz
    membw: float = 160_000.0      # shared-path capacity, instr-traffic/us
    table_ema: float = 0.5
    record_wf: bool = False
    # False | True | "v1" | "v2" — Pallas kernel generation (see SimStatic)
    use_pallas: Union[bool, str] = False
    # v2 blocked-(CU,)-grid tile size (None = monolithic kernel)
    pallas_block_cu: Optional[int] = None
    power: PWR.PowerConfig = PWR.DEFAULT  # V/f + IVR hardware regime
    seed: int = 0

    def static_part(self, n_epochs: Optional[int] = None) -> SimStatic:
        """The hashable jit key. ``n_epochs`` overrides the scan length
        (the sweep layer passes the max over a grid)."""
        return SimStatic(
            n_cu=self.n_cu, n_wf=self.n_wf,
            n_epochs=self.n_epochs if n_epochs is None else n_epochs,
            entries=self.entries, offset_blocks=self.offset_blocks,
            cus_per_table=self.cus_per_table,
            cus_per_domain=self.cus_per_domain,
            record_wf=self.record_wf, use_pallas=self.use_pallas,
            pallas_block_cu=self.pallas_block_cu,
            power=self.power.static_part())

    def axes(self) -> SimAxes:
        """The traced grid-point operand (logical epochs = ``n_epochs``)."""
        return SimAxes(
            epoch_us=jnp.float32(self.epoch_us),
            sigma=jnp.float32(self.sigma),
            cap_per_ghz=jnp.float32(self.cap_per_ghz),
            membw=jnp.float32(self.membw),
            table_ema=jnp.float32(self.table_ema),
            obj=jnp.asarray(objective_weights(self.objective)),
            n_ep=jnp.int32(self.n_epochs),
            power=self.power.axes())


class Carry(NamedTuple):
    pos: jnp.ndarray         # (CU,WF) absolute instruction index
    react_i0: jnp.ndarray    # (CU,) reactive CU-level state
    react_sens: jnp.ndarray
    wf_i0: jnp.ndarray       # (CU,WF) per-WF fallback state
    wf_sens: jnp.ndarray
    table: PRED.PCTable
    f_prev: jnp.ndarray      # (CU,)
    e_acc: jnp.ndarray       # (CU,) accumulated energy (for online Pbar)
    t_acc: jnp.ndarray       # () accumulated time


class EpochCtx(NamedTuple):
    """Frequency-independent per-epoch state, computed once and shared by
    every frequency row of the batched execute (forks + real execution)."""
    blk: jnp.ndarray    # (CU,WF) int32 starting PC block
    i0_l: jnp.ndarray   # (CU,WF) local i0 rate at blk
    s_l: jnp.ndarray    # (CU,WF) local sens rate at blk
    eps: jnp.ndarray    # (CU,WF) deterministic (block,loop,wf,cu)-keyed noise
    cum3: jnp.ndarray   # (2P+1,3) packed (cum_i0, cum_sens, cum_mem)
    cum_lo: jnp.ndarray  # (CU,WF,3) cum3 gathered at blk (window low side)


def _epoch_context(prog: Program, pos: jnp.ndarray, p_blocks,
                   seed) -> EpochCtx:
    blk = (pos.astype(jnp.int32) // INSTR_PER_BLOCK) % p_blocks  # (CU,WF)
    i0_l = prog.i0_rate[blk]
    s_l = prog.sens_rate[blk]
    # one packed gather row per window side: 12 contiguous bytes/index
    # instead of three strided single-float gathers; the low side depends
    # only on pos, so it is shared by all frequency rows. The packed
    # (2P+1,3) stack is a scan-invariant precomputed on Program.
    cum3 = prog.cum3
    cum_lo = cum3[blk]
    # deterministic (block, loop, wf, cu)-keyed noise — identical for every
    # fork and for the real execution (the paper's fork property). The seed
    # is carried as int32 end-to-end (a float32 seed aliases integers above
    # 2^24 to the same noise stream: consecutive large seeds silently share
    # a stream) and cast only here, split into exactly-representable
    # halves folded into ONE scalar phase: the low half keeps the
    # historical ``seed * 3.7`` term (seeds < 65536 reproduce the pre-int32
    # stream bitwise — the high term is an exact +0, and the array-side
    # graph is unchanged: one scalar-broadcast add, so XLA fusion and the
    # downstream reduction orders stay put); the high half rotates by a
    # golden-ratio multiple of 3.7 so nearby (lo, hi) pairs stay ulp-
    # separated. f32 cannot hold 2^32 distinct phases, so pathological
    # distant pairs can still collide — but no *consecutive* seeds do,
    # at any magnitude.
    loop = (pos // (INSTR_PER_BLOCK * p_blocks)).astype(jnp.float32)
    wf_id = jnp.arange(pos.shape[1], dtype=jnp.float32)[None, :]
    cu_id = jnp.arange(pos.shape[0], dtype=jnp.float32)[:, None]
    seed = jnp.asarray(seed, jnp.int32)
    s_lo = (seed % 65536).astype(jnp.float32)
    s_hi = (seed // 65536).astype(jnp.float32)
    seed_phase = s_lo * 3.7 + s_hi * 2.2867257  # 3.7 * golden ratio
    h = jnp.sin(blk * 12.9898 + loop * 78.233 + wf_id * 37.719
                + cu_id * 9.131 + seed_phase) * 43758.5453
    eps = (h - jnp.floor(h)) * 2.0 - 1.0
    return EpochCtx(blk=blk, i0_l=i0_l, s_l=s_l, eps=eps,
                    cum3=cum3, cum_lo=cum_lo)


class _SteadyParts(NamedTuple):
    """Intermediates of the steady-state execute for a ``(..., CU)`` batch of
    frequency rows. Fork rows consume only ``steady``; the selected row is
    completed into full hardware counters by ``_row_counters`` (so XLA DCEs
    the barrier/contention math for the 10 fork rows)."""
    steady: jnp.ndarray
    alloc: jnp.ndarray
    demand: jnp.ndarray
    i0w: jnp.ndarray
    sw: jnp.ndarray
    mfw: jnp.ndarray


def _steady_parts(ctx: EpochCtx, pos: jnp.ndarray,
                  f_cu: jnp.ndarray, p_blocks, ax: SimAxes) -> _SteadyParts:
    """Steady-state committed instructions at frequency rows ``f_cu`` of
    shape ``(..., CU)`` against a shared epoch context; all outputs carry
    the batch shape."""
    T = ax.epoch_us
    f_b = f_cu[..., :, None]                                  # (...,CU,1)
    est_instr = (ctx.i0_l + ctx.s_l * f_b) * T
    nblk = jnp.clip((est_instr / INSTR_PER_BLOCK).astype(jnp.int32) + 1,
                    1, p_blocks)
    wavg = (ctx.cum3[ctx.blk + nblk] - ctx.cum_lo) / nblk[..., None]
    i0w, sw, mfw = wavg[..., 0], wavg[..., 1], wavg[..., 2]
    demand = (i0w + sw * f_b) * T
    demand = demand * (1.0 + ax.sigma * ctx.eps)
    # oldest-first issue allocation (slot index = age priority)
    C = ax.cap_per_ghz * f_cu * T
    before = jnp.cumsum(demand, axis=-1) - demand
    alloc = jnp.clip(C[..., :, None] - before, 0.0, demand)
    # shared L2/DRAM bandwidth coupling across all CUs
    traffic = (alloc * mfw).sum(axis=(-2, -1))
    scale = jnp.minimum(1.0, ax.membw * T / jnp.maximum(traffic, 1e-6))
    steady = alloc * (1.0 - mfw * (1.0 - scale[..., None, None]))
    return _SteadyParts(steady, alloc, demand, i0w, sw, mfw)


def _row_counters(parts: _SteadyParts, pos: jnp.ndarray, f_cu: jnp.ndarray,
                  p_blocks
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Complete one frequency row into the full hardware-counter view.

    Workgroup barrier at each kernel-loop boundary: wavefronts wait for the
    slowest wave in their CU before starting the next iteration. This keeps
    a CU's waves phase-aligned (GPU kernels barrier/relaunch per loop) and
    is what gives CUs their strong fine-grain phase behavior (Figs 6-8).
    Barrier-idle time truncates *work* but controllers/estimators reason on
    steady-state throughput ("committed" counter continues to tick in HW).
    """
    f_b = f_cu[..., :, None]
    q = parts.alloc / jnp.maximum(parts.demand, 1e-6)
    plen = jnp.asarray(p_blocks * INSTR_PER_BLOCK, jnp.float32)
    tentative = pos + parts.steady
    group_min = tentative.min(axis=-1)                        # slowest wave
    boundary = (jnp.floor(group_min / plen) + 1.0) * plen     # (...,CU)
    committed = jnp.minimum(parts.steady,
                            jnp.maximum(boundary[..., :, None] - pos, 0.0))
    core_frac = parts.sw * f_b / jnp.maximum(parts.i0w + parts.sw * f_b, 1e-6)
    counters = {"committed": committed, "steady": parts.steady,
                "core_frac": core_frac, "issue_q": q, "mem_frac": parts.mfw}
    return committed, counters


def _execute_ctx(ctx: EpochCtx, pos: jnp.ndarray,
                 f_cu: jnp.ndarray, p_blocks, ax: SimAxes
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full execute (steady + barrier/contention counters) of ``f_cu``
    frequency rows of shape ``(..., CU)`` against a shared epoch context."""
    parts = _steady_parts(ctx, pos, f_cu, p_blocks, ax)
    return _row_counters(parts, pos, f_cu, p_blocks)


def epoch_execute(prog: Program, pos: jnp.ndarray, f_cu: jnp.ndarray,
                  sim: SimConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Ground-truth execution of one epoch at per-CU frequencies ``f_cu``.
    Deterministic in (pos, f) — this *is* the fork property."""
    ax = sim.axes()
    ctx = _epoch_context(prog, pos, prog.n_blocks, sim.seed)
    committed, counters = _execute_ctx(ctx, pos, f_cu, prog.n_blocks, ax)
    counters = dict(counters, start_block=ctx.blk)
    return committed, counters


def _predict_instr(i0_cu, sens_cu, st: SimStatic, ax: SimAxes):
    """(CU,) linear state -> predicted I at every ladder frequency,
    capacity-clipped. The ladder derives from the traced power regime."""
    F = PWR.freqs_ghz(ax.power, st.power.n_freqs)
    I = (i0_cu[:, None] + sens_cu[:, None] * F[None, :]) * ax.epoch_us
    cap = ax.cap_per_ghz * F[None, :] * ax.epoch_us * st.n_wf
    return jnp.clip(I, 0.0, cap)


# public alias for MechanismSpec.predict hooks: lower a per-CU linear model
# (rates in instr/us and instr/us/GHz) to the capacity-clipped (CU, 10)
# prediction the frequency controller consumes
predict_instr = _predict_instr


def _select_freq(I_pred_f: jnp.ndarray, st: SimStatic, ax: SimAxes,
                 pbar_dom: jnp.ndarray) -> jnp.ndarray:
    """Choose per-domain frequency minimizing the objective.

    For ED^nP the globally-optimal allocation equalizes the marginal
    energy-per-delay de/dd = -n*(E/D) across phases, so the correct greedy
    per-epoch cost is (P(f) + n*Pbar) / rate(f) where Pbar = E/D is the
    domain's accumulated average power (online Lagrangian; a naive P/I^(n+1)
    greedy systematically over/under-clocks heterogeneous phase mixes).

    The objective arrives lowered as ``ax.obj = [w_pbar, use_rate, capf]``
    (see ``objective_weights``) so all objectives share one traced path:
    EDP/ED^2P divide the Lagrangian power by the rate (``use_rate=1``,
    ``capf=0`` never penalizes), perf-cap objectives keep raw power and add
    a big penalty on frequencies below ``capf`` of the max-frequency rate.

    I_pred_f: (CU, n_freqs); pbar_dom: (n_dom,). Returns selected index (CU,).
    """
    F = PWR.freqs_ghz(ax.power, st.power.n_freqs)
    n_dom = st.n_cu // st.cus_per_domain
    I_dom = I_pred_f.reshape(n_dom, st.cus_per_domain, -1)
    act = I_pred_f / (ax.cap_per_ghz * F[None, :] * ax.epoch_us * st.n_wf)
    p_cu = PWR.power(F[None, :], act, ax.power)             # (CU,NF)
    P_dom = p_cu.reshape(n_dom, st.cus_per_domain, -1).sum(1)  # (dom,10)
    I_sum = jnp.maximum(I_dom.sum(1), 1e-3)                 # (dom,10)
    w_pbar, use_rate, capf = ax.obj[0], ax.obj[1], ax.obj[2]
    denom = jnp.where(use_rate > 0.0, I_sum, 1.0)
    infeasible = I_sum < capf * I_sum[:, -1:]
    cost = (P_dom + w_pbar * pbar_dom[:, None]) / denom + 1e9 * infeasible
    idx_dom = jnp.argmin(cost, axis=-1)                     # (dom,)
    return jnp.repeat(idx_dom, st.cus_per_domain)


def _true_wf_linear(c_f: jnp.ndarray, F: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """c_f: (NF, CU, WF) fork-committed at ladder ``F`` -> exact per-WF
    (i0_rate, sens)."""
    sens = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
    i0 = c_f[0] - sens * F[0]
    return i0, sens


def init_carry(p_blocks, st: SimStatic) -> Carry:
    """The scan-initial state for a ``p_blocks``-block program.

    Exposed so the sweep layer can build the (batched) initial carry
    *outside* the grid executables and donate its buffers to the dispatch
    (``jax.jit(..., donate_argnums)``): the runtime may then release the
    carry allocation as soon as the scan consumes it instead of pinning a
    protected input copy for the whole dispatch (it cannot alias into the
    trace outputs, whose shapes differ). Values are bitwise-identical to
    the in-trace construction (same ops, same dtypes)."""
    n_tables = max(st.n_cu // st.cus_per_table, 1)
    plen = jnp.asarray(p_blocks * INSTR_PER_BLOCK, jnp.float32)
    cu_off = (jnp.arange(st.n_cu, dtype=jnp.float32)[:, None] * 97.0) % plen
    wf_off = jnp.arange(st.n_wf, dtype=jnp.float32)[None, :] * 1.0
    pos0 = (cu_off + wf_off) % plen
    return Carry(
        pos=pos0,
        react_i0=jnp.full((st.n_cu,), 50.0),
        react_sens=jnp.full((st.n_cu,), 30.0),
        wf_i0=jnp.full((st.n_cu, st.n_wf), 1.2),
        wf_sens=jnp.full((st.n_cu, st.n_wf), 0.8),
        table=PRED.table_init(n_tables, st.entries),
        # F_STATIC of the DEFAULT ladder on purpose: the carry must not
        # depend on the traced power axes (it is built once per SimStatic
        # and donated); off-default regimes just see one initial
        # transition per CU, like real hardware coming out of a fixed
        # boot frequency
        f_prev=jnp.full((st.n_cu,), 1.7),
        # warm-start Pbar near the static-1.7 operating point
        e_acc=jnp.full((st.n_cu,), 0.42 * 20.0),
        t_acc=jnp.asarray(20.0),
    )


def _scan_sim(prog: Program, p_blocks, seed, st: SimStatic, ax: SimAxes,
              mech: Union[str, MechanismSpec, jnp.ndarray],
              carry0: Optional[Carry] = None) -> Dict[str, jnp.ndarray]:
    """The simulation scan. ``mech`` is either a concrete mechanism — a
    name or :class:`MechanismSpec`, resolved through the registry to a
    maximally specialized trace (fused 11-way execute for non-oracle fork
    mechanisms; ``predict``/``update`` hooks traced in for registered
    custom mechanisms) — or a traced int32 id into ``FORK_MECHS`` (one
    executable shared by all builtin fork mechanisms — the batched-sweep
    hot path; branch selection compares against the registry's frozen
    traced ids).

    ``p_blocks`` (logical block count; array may be padded beyond it),
    ``seed`` (int32 noise key) and the ``SimAxes`` grid point are all traced
    so the sweep layer can vmap over them. The scan runs to the static
    ``st.n_epochs``; epochs at index >= ``ax.n_ep`` are masked to zero in
    every output channel (the logical-epoch tail of a shorter grid point).
    ``carry0`` overrides the initial state (the sweep layer passes a
    donated ``init_carry``); ``None`` builds it in-trace.
    """
    static_mech = isinstance(mech, (str, MechanismSpec))
    NF = st.power.n_freqs
    F = PWR.freqs_ghz(ax.power, NF)   # traced ladder (endpoints are axes)
    T = ax.epoch_us
    n_dom = st.n_cu // st.cus_per_domain
    n_tables = max(st.n_cu // st.cus_per_table, 1)
    lat_us = PWR.transition_latency_us(ax.epoch_us, ax.power)
    # hoisted scan-body constants
    tid = jnp.arange(st.n_cu) // st.cus_per_table
    F_rows = jnp.broadcast_to(F[:, None], (NF, st.n_cu))  # (NF,CU)

    if static_mech:
        spec = MECH.resolve(mech)
        is_static_f = spec.family == "static"
        assert spec.static_fidx is None or spec.static_fidx < NF, \
            f"{spec.name}: static_fidx {spec.static_fidx} is off the " \
            f"{NF}-state ladder of this power regime"
        is_custom = spec.predict is not None
        is_pc = spec.family == "pc" and not is_custom
        is_react = spec.family == "reactive" and not is_custom
        is_oracle = spec.family == "oracle"
    else:
        spec = None
        is_static_f = is_custom = False
        is_pc = is_react = is_oracle = None  # resolved per-trace via mech id
    # Pallas generation select: "v2" is the fused fork--execute epoch
    # kernel (kernels.epoch_fused) and covers the builtin traced fork
    # family — every mechanism whose epoch is the standard predict ->
    # select -> 11-way execute -> estimate shape — both as a specialized
    # trace AND as the traced-mechanism-id executable the sweep layer
    # vmaps (family='fork' kernel mode), so one compiled fused kernel
    # serves every grid point. Non-capable specs (oracle/custom/static —
    # see MechanismSpec.v2_capable) and record_wf (per-WF fork channels
    # the fused kernel does not materialize) fall back to the unfused
    # body. "v1" (and v2-ineligible fallback) is the PC-table
    # predict/update kernel pair; True auto-selects v2 -> v1 -> jnp.
    mode = st.use_pallas
    assert mode in (False, True, "v1", "v2"), \
        f"use_pallas must be False|True|'v1'|'v2', got {mode!r}"
    use_pallas_v2 = (mode in (True, "v2") and not st.record_wf
                     and ((static_mech and spec.is_traced
                           and spec.v2_capable)
                          or (not static_mech and _FORK_V2_CAPABLE)))
    use_pallas = (not use_pallas_v2 and mode in (True, "v1", "v2")
                  and static_mech and not is_static_f
                  and not is_custom and st.n_cu % st.cus_per_table == 0)
    if use_pallas:
        from repro.kernels import pc_table as KPT
    if use_pallas_v2:
        from repro.kernels import epoch_fused as KEF

    def _pc_lookup(carry, idx_lu):
        """Table lookup + CU reduce + I(f) + capacity clip; jnp or Pallas."""
        if use_pallas:
            I_pc = KPT.pc_table_predict(
                carry.table.i0, carry.table.sens, carry.table.count,
                tid, idx_lu, carry.wf_i0, carry.wf_sens, F,
                epoch_us=T, cap_per_ghz=ax.cap_per_ghz)
            hit = (carry.table.count[tid[:, None], idx_lu] > 0) \
                .astype(jnp.float32)
        else:
            i0t, s_t, hit = PRED.table_lookup(carry.table, tid, idx_lu,
                                              carry.wf_i0, carry.wf_sens)
            I_pc = _predict_instr(i0t.sum(-1), s_t.sum(-1), st, ax)
        return I_pc, hit

    def _table_update(carry, idx_lu, i0_wf, s_wf):
        if use_pallas:
            G = st.cus_per_table
            shp = (n_tables, G * st.n_wf)
            i0n, sn, cn = KPT.pc_table_update(
                carry.table.i0, carry.table.sens, carry.table.count,
                idx_lu.reshape(shp), i0_wf.reshape(shp), s_wf.reshape(shp),
                ema=ax.table_ema)
            return PRED.PCTable(i0n, sn, cn)
        return PRED.table_update(carry.table, tid, idx_lu, i0_wf, s_wf,
                                 ax.table_ema)

    def body(carry: Carry, ep_i):
        pos = carry.pos
        ctx = _epoch_context(prog, pos, p_blocks, seed)

        hit_rate = None
        c_f = I_f = I_pred_f = idx_lu = None
        if is_static_f:
            fidx = jnp.full((st.n_cu,), spec.static_fidx, jnp.int32)
            f_sel = F[fidx]
            committed, ctr = _execute_ctx(ctx, pos, f_sel, p_blocks, ax)
        else:
            # --- predict I(f) from carry state (no forks needed) ----------
            idx_lu = PRED.table_index(ctx.blk, st.entries, st.offset_blocks)
            # custom pc-family specs keep the standard table machinery
            # (lookup telemetry here, counter-driven update below): their
            # predict hook reads carry.table and customizes only prediction
            if (not static_mech) or is_pc or (is_custom
                                              and spec.family == "pc"):
                I_pc, hit = _pc_lookup(carry, idx_lu)
                hit_rate = hit.mean()
            if (not static_mech) or is_react:
                I_react = _predict_instr(carry.react_i0, carry.react_sens,
                                         st, ax)
            if static_mech and is_custom:
                # registered mechanism: the spec's predictor hook supplies
                # I(f) from the same carry/context view the builtins see
                I_hook = spec.predict(carry, ctx, st, ax)
            pbar = (carry.e_acc / jnp.maximum(carry.t_acc, 1e-3)) \
                .reshape(n_dom, st.cus_per_domain).sum(1)

            if static_mech and is_oracle:
                # oracle's prediction IS this epoch's forks -> forks first,
                # then the mixed-frequency row (still sharing the context).
                c_f = _steady_parts(ctx, pos, F_rows, p_blocks, ax).steady
                I_f = c_f.sum(-1).T
                I_pred_f = I_f
                fidx = _select_freq(I_pred_f, st, ax, pbar)
                f_sel = F[fidx]
                committed, ctr = _execute_ctx(ctx, pos, f_sel, p_blocks, ax)
            else:
                # fused fork--pre-execute: for every non-oracle mechanism the
                # selection depends only on carry, so the 10 uniform fork
                # rows and the chosen mixed row run as one 11-way batched
                # execute over the shared context; barrier/contention
                # counters materialize only for row 10. (The traced family
                # therefore excludes oracle — run_suite routes it to its own
                # specialized executable.)
                if static_mech:
                    I_pred_f = I_hook if is_custom else \
                        (I_pc if is_pc else I_react)
                else:
                    I_pred_f = jnp.where(mech < _N_REACT, I_react, I_pc)
                fidx = _select_freq(I_pred_f, st, ax, pbar)
                f_all = jnp.concatenate([F_rows, F[fidx][None]], axis=0)
                parts = _steady_parts(ctx, pos, f_all, p_blocks, ax)
                c_f = parts.steady[:NF]                     # (NF,CU,WF)
                sel_parts = _SteadyParts(*(x[NF] for x in parts))
                committed, ctr = _row_counters(sel_parts, pos, f_all[NF],
                                               p_blocks)
                f_sel = f_all[NF]
                I_f = c_f.sum(-1).T                         # (CU,NF)

        # --- transition overhead + counter views --------------------------
        trans = (f_sel != carry.f_prev)
        committed = committed * (1.0 - lat_us / T * trans[:, None])
        I_actual = ctr["steady"].sum(-1)                 # (CU,) counter view
        work_actual = committed.sum(-1)                  # (CU,) real progress
        # --- accuracy of the prediction for THIS epoch --------------------
        if I_pred_f is not None:
            I_at_sel = jnp.take_along_axis(I_pred_f, fidx[:, None], 1)[:, 0]
            err = jnp.abs(I_at_sel - I_actual) / jnp.maximum(I_actual, 1e-3)
        else:
            err = jnp.zeros((st.n_cu,))
        # --- energy --------------------------------------------------------
        act = work_actual / (ax.cap_per_ghz * f_sel * T * st.n_wf)
        energy = PWR.power(f_sel, act, ax.power) * T \
            + PWR.transition_energy(carry.f_prev, f_sel, ax.power) * trans
        # --- estimation + state update -------------------------------------
        new = carry._replace(pos=pos + committed, f_prev=f_sel,
                             e_acc=carry.e_acc + energy,
                             t_acc=carry.t_acc + T)
        est_ctrs = dict(ctr, committed=ctr["steady"])
        if static_mech:
            if is_custom:
                if spec.family == "pc":
                    # standard counter-driven table maintenance (pcstall's
                    # estimator path) so a registered pc-family predictor
                    # sees a live table without reimplementing it
                    i0_wf, s_wf = EST.wf_stall_estimate(est_ctrs, f_sel)
                    i0_wf, s_wf = i0_wf / T, s_wf / T
                    tbl = _table_update(carry, idx_lu, i0_wf, s_wf)
                    new = new._replace(table=tbl, wf_i0=i0_wf,
                                       wf_sens=s_wf)
                if spec.update is not None:
                    upd = spec.update(est_ctrs, f_sel, I_f, carry, ctx,
                                      st, ax)
                    if upd is not None:
                        new = new._replace(react_i0=upd[0],
                                           react_sens=upd[1])
            elif is_react and not spec.fork_estimator:
                i0_cu, s_cu = EST.cu_estimate(est_ctrs, f_sel, spec.cu_model)
                new = new._replace(react_i0=i0_cu / T, react_sens=s_cu / T)
            elif is_react:  # fork-accurate reactive: exact linear from forks
                sens_cu = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
                i0_cu = I_f[:, 0] / T - sens_cu * F[0]
                new = new._replace(react_i0=i0_cu, react_sens=sens_cu)
            elif is_pc:
                if not spec.fork_estimator:  # counter-driven (pcstall)
                    i0_wf, s_wf = EST.wf_stall_estimate(est_ctrs, f_sel)
                else:  # exact per-WF linear model from the forks (accpc)
                    i0_wf, s_wf = _true_wf_linear(c_f, F)
                i0_wf, s_wf = i0_wf / T, s_wf / T
                tbl = _table_update(carry, idx_lu, i0_wf, s_wf)
                new = new._replace(table=tbl, wf_i0=i0_wf, wf_sens=s_wf)
        else:
            # traced mechanism id: evaluate every estimator (cheap next to
            # the batched executes) and select, so one executable serves the
            # whole fork-mechanism family under vmap. Case order follows
            # the registry's traced ids (asserted at import: counter models
            # 0..n-2, the fork-accurate reactive last).
            cu_ests = [EST.cu_estimate(est_ctrs, f_sel, s.cu_model)
                       for s in _REACT_SPECS if not s.fork_estimator]
            sens_ar = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
            i0_ar = I_f[:, 0] / T - sens_ar * F[0]
            sel = [mech == k for k in range(_N_REACT)]
            r_i0 = jnp.select(sel, [e[0] / T for e in cu_ests] + [i0_ar],
                              carry.react_i0)
            r_se = jnp.select(sel, [e[1] / T for e in cu_ests] + [sens_ar],
                              carry.react_sens)
            new = new._replace(react_i0=r_i0, react_sens=r_se)
            i0_est, s_est = EST.wf_stall_estimate(est_ctrs, f_sel)
            i0_tr, s_tr = _true_wf_linear(c_f, F)
            i0_wf = jnp.where(mech == _ID_CTR_PC, i0_est, i0_tr) / T
            s_wf = jnp.where(mech == _ID_CTR_PC, s_est, s_tr) / T
            tbl_u = _table_update(carry, idx_lu, i0_wf, s_wf)
            pc_now = functools.reduce(
                lambda a, b: a | b, [mech == i for i in _PC_IDS])
            tbl = jax.tree.map(lambda a, b: jnp.where(pc_now, a, b),
                               tbl_u, carry.table)
            new = new._replace(
                table=tbl,
                wf_i0=jnp.where(pc_now, i0_wf, carry.wf_i0),
                wf_sens=jnp.where(pc_now, s_wf, carry.wf_sens))
        # true CU sensitivity for phase-variability analyses
        if is_static_f:
            true_sens_cu = jnp.zeros((st.n_cu,))
        else:
            true_sens_cu = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
        ys = {"work": work_actual, "energy": energy, "err": err,
              "fidx": fidx.astype(jnp.int8), "true_sens": true_sens_cu}
        # emit the channel only when the spec declares it (custom pc specs
        # may decline), so run_sim and the sweep layer agree on the trace
        # schema; the traced family (spec is None) emits for all and the
        # sweep layer filters per spec on unpack
        if hit_rate is not None and (spec is None or spec.hit_telemetry):
            ys["hit_rate"] = hit_rate
        if st.record_wf and not is_static_f:
            ys["wf_sens"] = ((c_f[-1] - c_f[0]) / (F[-1] - F[0])) \
                .astype(jnp.float32)
            ys["wf_blk"] = ctx.blk.astype(jnp.int32)
        # logical-epoch mask: grid points shorter than the static scan
        # length report zeros past their tail (state keeps advancing, but
        # the scan is causal so live epochs are unaffected)
        live = ep_i < ax.n_ep
        ys = jax.tree.map(lambda v: jnp.where(live, v, jnp.zeros_like(v)), ys)
        return new, ys

    def body_v2(carry: Carry, ep_i):
        # the whole epoch — context, predict, select, 11-way execute,
        # counters, estimate, table update — is ONE fused kernel. The only
        # piece computed outside is the sin-hash noise: the kernel's module
        # docstring explains why eps must not be recomputed in a different
        # fusion context (the unused context gathers are DCE'd).
        eps = _epoch_context(prog, carry.pos, p_blocks, seed).eps
        out = KEF.epoch_fused(
            prog.i0_rate, prog.sens_rate, cum_t, carry.pos, F, eps,
            carry.f_prev, carry.e_acc, carry.t_acc,
            p_blocks=p_blocks, epoch_us=T, sigma=ax.sigma,
            cap_per_ghz=ax.cap_per_ghz, membw=ax.membw, obj=ax.obj,
            lat_us=lat_us, power=ax.power,
            cus_per_domain=st.cus_per_domain,
            table=carry.table, tid=tid, wf_i0=carry.wf_i0,
            wf_sens=carry.wf_sens,
            # the (9,)-packed scal operand makes every consumer of any
            # packed scalar depend on ALL of them in a jaxpr walk, so for
            # specialized table-free specs the EMA rides in as a trace
            # literal (value-unused) to keep the axis-liveness audit exact
            table_ema=(ax.table_ema if spec is None
                       or spec.family == "pc" else 0.0),
            offset_blocks=st.offset_blocks,
            react_i0=carry.react_i0, react_sens=carry.react_sens,
            **v2_kw)
        new = carry._replace(pos=out.pos, f_prev=out.f_sel,
                             e_acc=out.e_acc, t_acc=out.t_acc[0])
        if spec is None:
            # traced-id mode advances every state group; the kernel's
            # id-gated selects already kept the dead ones at carry values
            new = new._replace(table=out.table, wf_i0=out.wf_i0,
                               wf_sens=out.wf_sens, react_i0=out.react_i0,
                               react_sens=out.react_sens)
        elif spec.family == "pc":
            new = new._replace(table=out.table, wf_i0=out.wf_i0,
                               wf_sens=out.wf_sens)
        else:
            new = new._replace(react_i0=out.react_i0,
                               react_sens=out.react_sens)
        ys = {"work": out.work, "energy": out.energy, "err": out.err,
              "fidx": out.fidx.astype(jnp.int8),
              "true_sens": out.true_sens}
        # traced-id mode emits for all (like the jnp traced family; the
        # sweep layer filters per spec on unpack)
        if spec is None or (spec.family == "pc" and spec.hit_telemetry):
            ys["hit_rate"] = out.hit_rate[0]
        live = ep_i < ax.n_ep
        return new, jax.tree.map(
            lambda v: jnp.where(live, v, jnp.zeros_like(v)), ys)

    if use_pallas_v2:
        # three contiguous gather rows per window side (see epoch_fused);
        # scan-invariant, hoisted out of the body
        cum_t = jnp.transpose(prog.cum3)
        if static_mech:
            v2_kw = dict(family=spec.family,
                         fork_estimator=spec.fork_estimator,
                         cu_model=spec.cu_model)
        else:
            # the traced-mechanism-id kernel mode: mech rides in as a
            # traced operand, and the registry-derived id layout becomes
            # kernel statics (counter estimators in id order, table ids)
            v2_kw = dict(family="fork", mech=mech,
                         react_models=tuple(
                             s.cu_model for s in _REACT_SPECS
                             if not s.fork_estimator),
                         pc_ids=_PC_IDS, id_ctr_pc=_ID_CTR_PC,
                         block_cu=st.pallas_block_cu)
    if carry0 is None:
        carry0 = init_carry(p_blocks, st)
    _, ys = lax.scan(body_v2 if use_pallas_v2 else body, carry0,
                     jnp.arange(st.n_epochs, dtype=jnp.int32))
    return ys


@functools.partial(jax.jit, static_argnames=("st", "mechanism"))
def _run_sim_jit(prog: Program, p_blocks, seed, ax: SimAxes, st: SimStatic,
                 mechanism: MechanismSpec) -> Dict[str, jnp.ndarray]:
    return _scan_sim(prog, p_blocks, seed, st, ax, mechanism)


def seed_i32(seeds) -> np.ndarray:
    """Fold integer seeds of any width into int32 by keeping the low 32
    bits (two's complement; masked in Python so arbitrary-width ints never
    overflow). The noise hash keys on int32; a deterministic wrap for
    hash/time-derived 64-bit seeds beats both an OverflowError and the old
    silent float32 aliasing."""
    scalar = np.ndim(seeds) == 0
    vals = [seeds] if scalar else list(seeds)
    folded = np.asarray([int(s) & 0xFFFFFFFF for s in vals],
                        np.uint32).astype(np.int32)
    return folded[0] if scalar else folded


def run_sim(prog: Program, sim: SimConfig,
            mechanism: Union[str, MechanismSpec]) -> Dict[str, np.ndarray]:
    """Simulate ``mechanism`` (a registered name or a ``MechanismSpec``)
    on ``prog``. Returns per-epoch traces.

    Compile-once: the scan is traced at most once per (SimStatic, mechanism
    spec, program shape) — subsequent calls, *including ones that change
    only traced axes like epoch_us/sigma/objective*, dispatch a cached XLA
    executable.
    """
    spec = MECH.resolve(mechanism)
    assert sim.n_cu % sim.cus_per_domain == 0
    ys = _run_sim_jit(prog, jnp.int32(prog.n_blocks),
                      jnp.asarray(seed_i32(sim.seed)), sim.axes(),
                      sim.static_part(), spec)
    return {k: np.asarray(v) for k, v in ys.items()}


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def prediction_accuracy(trace: Dict[str, np.ndarray], warmup: int = 50) -> float:
    err = trace["err"][warmup:]
    return float(np.clip(1.0 - np.mean(np.clip(err, 0, 1)), 0.0, 1.0))


def ednp(trace: Dict[str, np.ndarray], work_budget: float, epoch_us: float,
         n: int = 2) -> Tuple[float, float, float]:
    """(E, D, E*D^n) to complete ``work_budget`` total instructions."""
    cum_work = np.cumsum(trace["work"].sum(-1))
    cum_energy = np.cumsum(trace["energy"].sum(-1))
    if cum_work[-1] < work_budget:  # extrapolate at terminal rate
        rate = trace["work"].sum(-1)[-200:].mean() / epoch_us
        p_rate = trace["energy"].sum(-1)[-200:].mean() / epoch_us
        extra_t = (work_budget - cum_work[-1]) / rate
        D = len(cum_work) * epoch_us + extra_t
        E = cum_energy[-1] + p_rate * extra_t
    else:
        i = int(np.searchsorted(cum_work, work_budget))
        frac = ((work_budget - (cum_work[i - 1] if i else 0.0))
                / max(cum_work[i] - (cum_work[i - 1] if i else 0.0), 1e-9))
        D = (i + frac) * epoch_us
        E = (cum_energy[i - 1] if i else 0.0) + frac * (
            cum_energy[i] - (cum_energy[i - 1] if i else 0.0))
    return E, D, E * D ** n


def run_workload(prog: Program, sim: SimConfig, mechanisms=MECHANISMS,
                 n: int = 2, baseline: Union[str, MechanismSpec] = "static17"
                 ) -> Dict[str, Dict[str, float]]:
    """Run a mechanism suite; ED^nP normalized to ``baseline`` (any
    registered mechanism; default the paper's static 1.7 GHz)."""
    base_spec = MECH.resolve(baseline)
    base = run_sim(prog, sim, base_spec)
    budget = 0.9 * base["work"].sum()
    out: Dict[str, Dict[str, float]] = {}
    E0, D0, M0 = ednp(base, budget, sim.epoch_us, n)
    for mech in mechanisms:
        spec = MECH.resolve(mech)
        tr = base if spec.name == base_spec.name else run_sim(prog, sim, spec)
        E, D, M = ednp(tr, budget, sim.epoch_us, n)
        out[spec.name] = {
            "accuracy": prediction_accuracy(tr)
            if spec.family != "static" else float("nan"),
            "E": E, "D": D, "ednp": M, "ednp_norm": M / M0,
            "energy_norm": E / E0, "delay_norm": D / D0,
        }
    return out
