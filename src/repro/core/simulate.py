"""Fine-grain DVFS simulation engine (paper §5 methodology, in JAX).

One ``lax.scan`` step = one fixed-time epoch (paper §3.1):

  1. *fork--pre-execute oracle* (paper Fig 13): the epoch is evaluated at all
     10 V/f states from bit-identical starting conditions via ``vmap`` — a
     functional simulator needs no process forking, and the per-epoch noise
     is keyed by (block, loop-iteration, wavefront) so forks see identical
     stochasticity, exactly like the paper's forked gem5 processes;
  2. the mechanism under test predicts next-epoch instructions I(f);
  3. the controller picks the per-domain frequency optimizing the objective;
  4. the epoch is (re-)executed with the chosen mixed per-CU frequencies;
  5. estimators digest the epoch's counters and update predictor state.

Ground-truth execution model: wavefront at PC block b commits
``(i0 + sens*f)*T`` instructions (window-averaged over the blocks traversed),
subject to (a) oldest-first issue-capacity contention within the CU
(Fig 11a) and (b) a shared L2/DRAM bandwidth cap across CUs (the FwdSoft
L2-thrash second-order effect, §6.2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import estimators as EST
from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core.workloads import INSTR_PER_BLOCK, Program

MECHANISMS = ("static13", "static17", "static22",
              "stall", "lead", "crit", "crisp",
              "accreac", "pcstall", "accpc", "oracle")


@dataclass(frozen=True)
class SimConfig:
    n_cu: int = 64
    n_wf: int = 40
    epoch_us: float = 1.0
    n_epochs: int = 1500
    entries: int = 128
    offset_blocks: int = 8        # blocks/entry: 128 entries cover a 1024-block loop
    cus_per_table: int = 1
    cus_per_domain: int = 1
    objective: str = "ed2p"       # 'edp' | 'ed2p' | 'perfcap05' | 'perfcap10'
    sigma: float = 0.06           # same-PC iteration noise (Fig 10 ~10%)
    cap_per_ghz: float = 5500.0   # CU issue capacity, instr/us per GHz
    membw: float = 160_000.0      # shared-path capacity, instr-traffic/us
    table_ema: float = 0.5
    record_wf: bool = False
    seed: int = 0


class Carry(NamedTuple):
    pos: jnp.ndarray         # (CU,WF) absolute instruction index
    react_i0: jnp.ndarray    # (CU,) reactive CU-level state
    react_sens: jnp.ndarray
    wf_i0: jnp.ndarray       # (CU,WF) per-WF fallback state
    wf_sens: jnp.ndarray
    table: PRED.PCTable
    f_prev: jnp.ndarray      # (CU,)
    e_acc: jnp.ndarray       # (CU,) accumulated energy (for online Pbar)
    t_acc: jnp.ndarray       # () accumulated time


def epoch_execute(prog: Program, pos: jnp.ndarray, f_cu: jnp.ndarray,
                  sim: SimConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Ground-truth execution of one epoch at per-CU frequencies ``f_cu``.
    Deterministic in (pos, f) — this *is* the fork property."""
    T = sim.epoch_us
    P = prog.n_blocks
    blk = (pos.astype(jnp.int32) // INSTR_PER_BLOCK) % P  # (CU,WF)
    f_b = f_cu[:, None]
    i0_l = prog.i0_rate[blk]
    s_l = prog.sens_rate[blk]
    est_instr = (i0_l + s_l * f_b) * T
    nblk = jnp.clip((est_instr / INSTR_PER_BLOCK).astype(jnp.int32) + 1, 1, P)

    def wavg(cum):
        return (cum[blk + nblk] - cum[blk]) / nblk

    i0w, sw, mfw = wavg(prog.cum_i0), wavg(prog.cum_sens), wavg(prog.cum_mem)
    demand = (i0w + sw * f_b) * T
    # deterministic (block, loop, wf, cu)-keyed noise
    loop = (pos // (INSTR_PER_BLOCK * P)).astype(jnp.float32)
    wf_id = jnp.arange(demand.shape[1], dtype=jnp.float32)[None, :]
    cu_id = jnp.arange(demand.shape[0], dtype=jnp.float32)[:, None]
    h = jnp.sin(blk * 12.9898 + loop * 78.233 + wf_id * 37.719
                + cu_id * 9.131 + sim.seed * 3.7) * 43758.5453
    eps = (h - jnp.floor(h)) * 2.0 - 1.0
    demand = demand * (1.0 + sim.sigma * eps)
    # oldest-first issue allocation (slot index = age priority)
    C = sim.cap_per_ghz * f_cu * T
    before = jnp.cumsum(demand, axis=1) - demand
    alloc = jnp.clip(C[:, None] - before, 0.0, demand)
    q = alloc / jnp.maximum(demand, 1e-6)
    # shared L2/DRAM bandwidth coupling across all CUs
    traffic = (alloc * mfw).sum()
    scale = jnp.minimum(1.0, sim.membw * T / jnp.maximum(traffic, 1e-6))
    steady = alloc * (1.0 - mfw * (1.0 - scale))
    # workgroup barrier at each kernel-loop boundary: wavefronts wait for the
    # slowest wave in their CU before starting the next iteration. This keeps
    # a CU's waves phase-aligned (GPU kernels barrier/relaunch per loop) and
    # is what gives CUs their strong fine-grain phase behavior (Figs 6-8).
    # Barrier-idle time truncates *work* but controllers/estimators reason on
    # steady-state throughput ("committed" counter continues to tick in HW).
    plen = float(P * INSTR_PER_BLOCK)
    tentative = pos + steady
    group_min = tentative.min(axis=1)                           # slowest wave
    boundary = (jnp.floor(group_min / plen) + 1.0) * plen       # (CU,)
    committed = jnp.minimum(steady, jnp.maximum(boundary[:, None] - pos, 0.0))
    core_frac = sw * f_b / jnp.maximum(i0w + sw * f_b, 1e-6)
    counters = {"committed": committed, "steady": steady, "core_frac": core_frac,
                "issue_q": q, "mem_frac": mfw, "start_block": blk}
    return committed, counters


def _predict_instr(i0_cu, sens_cu, sim: SimConfig):
    """(CU,) linear state -> predicted I at all 10 freqs, capacity-clipped."""
    F = PWR.FREQS_GHZ
    I = (i0_cu[:, None] + sens_cu[:, None] * F[None, :]) * sim.epoch_us
    cap = sim.cap_per_ghz * F[None, :] * sim.epoch_us * sim.n_wf
    return jnp.clip(I, 0.0, cap)


def _select_freq(I_pred_f: jnp.ndarray, sim: SimConfig,
                 pbar_dom: jnp.ndarray) -> jnp.ndarray:
    """Choose per-domain frequency minimizing the objective.

    For ED^nP the globally-optimal allocation equalizes the marginal
    energy-per-delay de/dd = -n*(E/D) across phases, so the correct greedy
    per-epoch cost is (P(f) + n*Pbar) / rate(f) where Pbar = E/D is the
    domain's accumulated average power (online Lagrangian; a naive P/I^(n+1)
    greedy systematically over/under-clocks heterogeneous phase mixes).

    I_pred_f: (CU, 10); pbar_dom: (n_dom,). Returns selected index (CU,).
    """
    F = PWR.FREQS_GHZ
    n_dom = sim.n_cu // sim.cus_per_domain
    I_dom = I_pred_f.reshape(n_dom, sim.cus_per_domain, -1)
    act = I_pred_f / (sim.cap_per_ghz * F[None, :] * sim.epoch_us * sim.n_wf)
    p_cu = PWR.power(F[None, :], act)                       # (CU,10)
    P_dom = p_cu.reshape(n_dom, sim.cus_per_domain, -1).sum(1)  # (dom,10)
    I_sum = jnp.maximum(I_dom.sum(1), 1e-3)                 # (dom,10)
    if sim.objective == "edp":
        cost = (P_dom + pbar_dom[:, None]) / I_sum
    elif sim.objective == "ed2p":
        cost = (P_dom + 2.0 * pbar_dom[:, None]) / I_sum
    elif sim.objective.startswith("perfcap"):
        capf = 1.0 - float(sim.objective[-2:]) / 100.0
        feasible = I_sum >= capf * I_sum[:, -1:]
        cost = P_dom + 1e9 * (~feasible)
    else:
        raise ValueError(sim.objective)
    idx_dom = jnp.argmin(cost, axis=-1)                     # (dom,)
    return jnp.repeat(idx_dom, sim.cus_per_domain)


def _true_wf_linear(c_f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """c_f: (10, CU, WF) fork-committed -> exact per-WF (i0_rate, sens)."""
    F = PWR.FREQS_GHZ
    sens = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
    i0 = c_f[0] - sens * F[0]
    return i0, sens


def run_sim(prog: Program, sim: SimConfig, mechanism: str) -> Dict[str, np.ndarray]:
    """Simulate ``mechanism`` on ``prog``. Returns per-epoch traces."""
    assert mechanism in MECHANISMS, mechanism
    assert sim.n_cu % sim.cus_per_domain == 0
    n_tables = max(sim.n_cu // sim.cus_per_table, 1)
    T = sim.epoch_us
    F = PWR.FREQS_GHZ
    static_f = {"static13": 0, "static17": 4, "static22": 9}
    needs_forks = mechanism not in static_f
    is_pc = mechanism in ("pcstall", "accpc")
    lat_us = PWR.transition_latency_us(sim.epoch_us)

    def body(carry: Carry, _):
        pos = carry.pos
        # --- fork--pre-execute at all 10 uniform frequencies -------------
        if needs_forks:
            _, ctr_f = jax.vmap(lambda f: epoch_execute(
                prog, pos, jnp.full((sim.n_cu,), f), sim))(F)
            c_f = ctr_f["steady"]                              # (10,CU,WF)
            I_f = c_f.sum(-1).T                                # (CU,10)
        else:
            c_f = None
            I_f = None
        # --- predict next-epoch I(f) --------------------------------------
        if mechanism in static_f:
            fidx = jnp.full((sim.n_cu,), static_f[mechanism], jnp.int32)
            I_pred_f = None
        else:
            if mechanism == "oracle":
                I_pred_f = I_f
            elif is_pc:
                P_ = prog.n_blocks
                nxt_blk = (pos.astype(jnp.int32) // INSTR_PER_BLOCK) % P_
                idx = PRED.table_index(nxt_blk, sim.entries, sim.offset_blocks)
                tid = jnp.arange(sim.n_cu) // sim.cus_per_table
                i0w, sw, hit = PRED.table_lookup(carry.table, tid, idx,
                                                 carry.wf_i0, carry.wf_sens)
                I_pred_f = _predict_instr(i0w.sum(-1), sw.sum(-1), sim)
                hit_rate = hit.mean()
            else:  # reactive CU-level
                I_pred_f = _predict_instr(carry.react_i0, carry.react_sens, sim)
            n_dom = sim.n_cu // sim.cus_per_domain
            pbar = (carry.e_acc / jnp.maximum(carry.t_acc, 1e-3)) \
                .reshape(n_dom, sim.cus_per_domain).sum(1)
            fidx = _select_freq(I_pred_f, sim, pbar)
        f_sel = F[fidx]
        # --- real execution at mixed per-CU frequencies -------------------
        committed, counters = epoch_execute(prog, pos, f_sel, sim)
        trans = (f_sel != carry.f_prev)
        committed = committed * (1.0 - lat_us / T * trans[:, None])
        I_actual = counters["steady"].sum(-1)                # (CU,) counter view
        work_actual = committed.sum(-1)                      # (CU,) real progress
        # --- accuracy of the prediction for THIS epoch --------------------
        if I_pred_f is not None:
            I_at_sel = jnp.take_along_axis(I_pred_f, fidx[:, None], 1)[:, 0]
            err = jnp.abs(I_at_sel - I_actual) / jnp.maximum(I_actual, 1e-3)
        else:
            err = jnp.zeros((sim.n_cu,))
        # --- energy --------------------------------------------------------
        act = work_actual / (sim.cap_per_ghz * f_sel * T * sim.n_wf)
        energy = PWR.power(f_sel, act) * T \
            + PWR.transition_energy(carry.f_prev, f_sel) * trans
        # --- estimation + state update -------------------------------------
        new = carry._replace(pos=pos + committed, f_prev=f_sel,
                             e_acc=carry.e_acc + energy,
                             t_acc=carry.t_acc + T)
        est_ctrs = dict(counters, committed=counters["steady"])
        if mechanism in ("stall", "lead", "crit", "crisp"):
            i0_cu, s_cu = EST.cu_estimate(est_ctrs, f_sel, mechanism)
            new = new._replace(react_i0=i0_cu / T, react_sens=s_cu / T)
        elif mechanism == "accreac":
            sens_cu = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
            i0_cu = I_f[:, 0] / T - sens_cu * F[0]
            new = new._replace(react_i0=i0_cu, react_sens=sens_cu)
        elif is_pc:
            if mechanism == "pcstall":
                i0_wf, s_wf = EST.wf_stall_estimate(est_ctrs, f_sel)
                i0_wf, s_wf = i0_wf / T, s_wf / T
            else:  # accpc: exact per-WF linear model from the forks
                i0_wf, s_wf = _true_wf_linear(c_f)
                i0_wf, s_wf = i0_wf / T, s_wf / T
            idx = PRED.table_index(counters["start_block"], sim.entries,
                                   sim.offset_blocks)
            tid = jnp.arange(sim.n_cu) // sim.cus_per_table
            tbl = PRED.table_update(carry.table, tid, idx, i0_wf, s_wf,
                                    sim.table_ema)
            new = new._replace(table=tbl, wf_i0=i0_wf, wf_sens=s_wf)
        # true CU sensitivity for phase-variability analyses
        if needs_forks:
            true_sens_cu = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
        else:
            true_sens_cu = jnp.zeros((sim.n_cu,))
        ys = {"work": work_actual, "energy": energy, "err": err,
              "fidx": fidx.astype(jnp.int8), "true_sens": true_sens_cu}
        if is_pc:
            ys["hit_rate"] = hit_rate
        if sim.record_wf and needs_forks:
            ys["wf_sens"] = ((c_f[-1] - c_f[0]) / (F[-1] - F[0])).astype(jnp.float32)
            ys["wf_blk"] = counters["start_block"].astype(jnp.int32)
        return new, ys

    plen = prog.n_blocks * INSTR_PER_BLOCK
    cu_off = (jnp.arange(sim.n_cu, dtype=jnp.float32)[:, None] * 97.0) % plen
    wf_off = jnp.arange(sim.n_wf, dtype=jnp.float32)[None, :] * 1.0
    pos0 = (cu_off + wf_off) % plen
    carry0 = Carry(
        pos=pos0,
        react_i0=jnp.full((sim.n_cu,), 50.0),
        react_sens=jnp.full((sim.n_cu,), 30.0),
        wf_i0=jnp.full((sim.n_cu, sim.n_wf), 1.2),
        wf_sens=jnp.full((sim.n_cu, sim.n_wf), 0.8),
        table=PRED.table_init(n_tables, sim.entries),
        f_prev=jnp.full((sim.n_cu,), 1.7),
        # warm-start Pbar near the static-1.7 operating point
        e_acc=jnp.full((sim.n_cu,), 0.42 * 20.0),
        t_acc=jnp.asarray(20.0),
    )
    _, ys = lax.scan(body, carry0, None, length=sim.n_epochs)
    return {k: np.asarray(v) for k, v in ys.items()}


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def prediction_accuracy(trace: Dict[str, np.ndarray], warmup: int = 50) -> float:
    err = trace["err"][warmup:]
    return float(np.clip(1.0 - np.mean(np.clip(err, 0, 1)), 0.0, 1.0))


def ednp(trace: Dict[str, np.ndarray], work_budget: float, epoch_us: float,
         n: int = 2) -> Tuple[float, float, float]:
    """(E, D, E*D^n) to complete ``work_budget`` total instructions."""
    cum_work = np.cumsum(trace["work"].sum(-1))
    cum_energy = np.cumsum(trace["energy"].sum(-1))
    if cum_work[-1] < work_budget:  # extrapolate at terminal rate
        rate = trace["work"].sum(-1)[-200:].mean() / epoch_us
        p_rate = trace["energy"].sum(-1)[-200:].mean() / epoch_us
        extra_t = (work_budget - cum_work[-1]) / rate
        D = len(cum_work) * epoch_us + extra_t
        E = cum_energy[-1] + p_rate * extra_t
    else:
        i = int(np.searchsorted(cum_work, work_budget))
        frac = ((work_budget - (cum_work[i - 1] if i else 0.0))
                / max(cum_work[i] - (cum_work[i - 1] if i else 0.0), 1e-9))
        D = (i + frac) * epoch_us
        E = (cum_energy[i - 1] if i else 0.0) + frac * (
            cum_energy[i] - (cum_energy[i - 1] if i else 0.0))
    return E, D, E * D ** n


def run_workload(prog: Program, sim: SimConfig, mechanisms=MECHANISMS,
                 n: int = 2) -> Dict[str, Dict[str, float]]:
    """Run a mechanism suite; ED^nP normalized to static17."""
    base = run_sim(prog, sim, "static17")
    budget = 0.9 * base["work"].sum()
    out: Dict[str, Dict[str, float]] = {}
    E0, D0, M0 = ednp(base, budget, sim.epoch_us, n)
    for mech in mechanisms:
        tr = base if mech == "static17" else run_sim(prog, sim, mech)
        E, D, M = ednp(tr, budget, sim.epoch_us, n)
        out[mech] = {
            "accuracy": prediction_accuracy(tr) if mech not in
            ("static13", "static17", "static22") else float("nan"),
            "E": E, "D": D, "ednp": M, "ednp_norm": M / M0,
            "energy_norm": E / E0, "delay_norm": D / D0,
        }
    return out
