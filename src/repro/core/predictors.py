"""Prediction mechanisms (paper §4.3-4.4, Table III).

The PC-indexed sensitivity table (PCSTALL's core): one table per
``cus_per_table`` CUs, ``entries`` slots, each slot holding a running
(i0, sens) estimate for the time-epoch that *starts* at that PC. Lookup uses
every wavefront's next starting PC; update scatters this epoch's per-WF
estimates keyed by its starting PC. Both are O(WF) gathers/scatters — the
hardware table of Table I (128 entries, ~328B/instance).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class PCTable(NamedTuple):
    i0: jnp.ndarray     # (n_tables, entries)
    sens: jnp.ndarray   # (n_tables, entries)
    count: jnp.ndarray  # (n_tables, entries) update count (0 = invalid)


def table_init(n_tables: int, entries: int) -> PCTable:
    z = jnp.zeros((n_tables, entries), jnp.float32)
    return PCTable(z, z, z)


def table_index(block: jnp.ndarray, entries: int, offset_blocks: int) -> jnp.ndarray:
    """PC -> table slot. ``offset_blocks`` = blocks per entry (paper's PC
    offset bits; 1 block = 4 instructions = the paper's 4-bit sweet spot)."""
    return (block // offset_blocks) % entries


def table_update(tbl: PCTable, tid: jnp.ndarray, idx: jnp.ndarray,
                 i0: jnp.ndarray, sens: jnp.ndarray, ema: float = 0.5) -> PCTable:
    """Scatter per-WF estimates. tid (CU,), idx/i0/sens (CU,WF).
    Collisions within an epoch are averaged; across epochs EMA-blended.

    The three accumulators (i0, sens, count) are packed into one (T*E, 3)
    scatter-add — one pass over the indices instead of three."""
    n_tables, entries = tbl.i0.shape
    flat = (tid[:, None] * entries + idx).reshape(-1)
    vals = jnp.stack([i0.reshape(-1), sens.reshape(-1),
                      jnp.ones_like(flat, jnp.float32)], axis=-1)   # (N,3)
    acc = jnp.zeros((n_tables * entries, 3), jnp.float32).at[flat].add(vals)
    acc = acc.reshape(n_tables, entries, 3)
    isum, ssum, cnt = acc[..., 0], acc[..., 1], acc[..., 2]
    snew = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), 0.0)
    inew = jnp.where(cnt > 0, isum / jnp.maximum(cnt, 1), 0.0)
    fresh = (tbl.count == 0) & (cnt > 0)
    blend = jnp.where(fresh, 1.0, jnp.where(cnt > 0, ema, 0.0))
    return PCTable(
        i0=tbl.i0 * (1 - blend) + inew * blend,
        sens=tbl.sens * (1 - blend) + snew * blend,
        count=tbl.count + cnt,
    )


def table_lookup(tbl: PCTable, tid: jnp.ndarray, idx: jnp.ndarray,
                 fb_i0: jnp.ndarray, fb_sens: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-WF lookup with reactive fallback on miss.
    Returns (i0, sens, hit) each (CU,WF)."""
    i0 = tbl.i0[tid[:, None], idx]
    sens = tbl.sens[tid[:, None], idx]
    hit = tbl.count[tid[:, None], idx] > 0
    return (jnp.where(hit, i0, fb_i0), jnp.where(hit, sens, fb_sens),
            hit.astype(jnp.float32))
