import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  jit(step).lower(*abstract_inputs).compile()
must succeed on the single-pod (16,16) mesh and the 2-pod (2,16,16) mesh.
Records memory_analysis, cost_analysis, and the collective-bytes schedule
(parsed from optimized HLO) into experiments/dryrun/*.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.configs.base import TrainConfig
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shard
from repro.train.train_step import make_decode_step, make_prefill_step, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str):
    """Sum result bytes of every collective op in optimized per-device HLO."""
    totals = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        op = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(0))[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if nbytes:
            totals[op] = totals.get(op, 0) + nbytes
    totals["total"] = sum(totals.values())
    return totals


def make_factored_mesh():
    """(data=16, expert=4, tp=4): EP x TP hybrid for MoE archs whose expert
    count doesn't divide 16 (qwen2-moe: 60 % 4 == 0)."""
    import jax as _jax
    return _jax.make_mesh((16, 4, 4), ("data", "expert", "tp"))


def build_cell(arch: str, shape_name: str, mesh, microbatches: int = 1,
               grad_compression: str = "none", remat: str = "full"):
    """Returns (jitted fn, abstract inputs) for one cell on a mesh."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), remat=remat)
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    axes = shard.mesh_axis_sizes(mesh)
    tc = TrainConfig(microbatches=microbatches, grad_compression=grad_compression)

    from repro.models import act_sharding as AS
    dp = shard.batch_axes(axes, shape.global_batch // (microbatches
                          if shape.kind == "train" else 1))
    dp_size = int(np.prod([axes[a] for a in dp])) if dp else 1
    model_ax = ("expert", "tp") if "tp" in axes else "model"
    model_sz = (axes.get("expert", 1) * axes.get("tp", 1) if "tp" in axes
                else axes.get("model", 1))
    AS.set_activation_axes(dp, model_ax, batch_size=dp_size, model_size=model_sz)

    if shape.kind == "train":
        inputs = ispec.input_specs(cfg, shape, tc)
        state_sp = shard.state_specs(cfg, inputs[0], axes)
        batch_sp = shard.batch_specs(cfg, inputs[1], axes, microbatched=True)
        in_sh = (shard.to_shardings(mesh, state_sp), shard.to_shardings(mesh, batch_sp))
        fn = jax.jit(make_train_step(cfg, tc), in_shardings=in_sh,
                     donate_argnums=(0,))
    elif shape.kind == "prefill":
        inputs = ispec.input_specs(cfg, shape, tc)
        p_sp = shard.param_specs(cfg, inputs[0], axes)
        b_sp = shard.batch_specs(cfg, inputs[1], axes, microbatched=False)
        in_sh = (shard.to_shardings(mesh, p_sp), shard.to_shardings(mesh, b_sp))
        fn = jax.jit(make_prefill_step(cfg), in_shardings=in_sh)
    else:
        inputs = ispec.input_specs(cfg, shape, tc)
        p_sp = shard.param_specs(cfg, inputs[0], axes)
        c_sp = shard.cache_specs(cfg, inputs[1], axes)
        t_sp = shard.batch_specs(cfg, {"t": inputs[2]}, axes, microbatched=False)["t"]
        in_sh = (shard.to_shardings(mesh, p_sp), shard.to_shardings(mesh, c_sp),
                 jax.sharding.NamedSharding(mesh, t_sp))
        fn = jax.jit(make_decode_step(cfg), in_shardings=in_sh, donate_argnums=(1,))
    return fn, inputs, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, ep_mesh: bool = False, **kw):
    mesh = make_factored_mesh() if ep_mesh else make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, inputs, cfg, shape = build_cell(arch, shape_name, mesh, **kw)
    with jax.sharding.set_mesh(mesh):
        lowered = fn.lower(*inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            mem_d = {k: int(getattr(mem, k)) for k in
                     ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "generated_code_size_in_bytes")
                     if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover
            mem_d = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            cost_d = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and k in
                      ("flops", "bytes accessed", "transcendentals",
                       "bytes accessed output", "optimal_seconds")}
        except Exception as e:  # pragma: no cover
            cost_d = {"error": str(e)}
        hlo_txt = compiled.as_text()
        coll = collective_bytes(hlo_txt)
        from repro.roofline.hlo_analysis import analyze, roofline_terms
        tripaware = analyze(hlo_txt)
        terms = roofline_terms(tripaware)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": ("16x4x4ep" if ep_mesh else
                 "pod2x16x16" if multi_pod else "16x16"),
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d, "cost": cost_d, "collectives": coll,
        "tripaware": tripaware, "roofline": terms,
        "options": kw,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--tag", default="")
    ap.add_argument("--ep-mesh", action="store_true",
                    help="factored (data,expert,tp)=(16,4,4) mesh")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = []
    if args.single_pod or args.all or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or args.all:
        meshes.append(True)

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in shapes_for(cfg)]
                  if (args.all or not args.shape) else [args.shape])
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'mp' if mp else 'sp'}"
                if args.tag:
                    tag += f"_{args.tag}"
                try:
                    rec = run_cell(arch, shape_name, mp,
                                   ep_mesh=args.ep_mesh,
                                   microbatches=args.microbatches,
                                   remat=args.remat,
                                   grad_compression=args.grad_compression)
                    (OUT_DIR / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                    print(f"OK   {tag:48s} lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops', 0):.3e} "
                          f"coll={rec['collectives'].get('total', 0):.3e}B")
                except Exception as e:
                    failures.append(tag)
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("all requested dry-run cells passed")


if __name__ == "__main__":
    main()
