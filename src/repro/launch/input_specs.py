"""ShapeDtypeStruct stand-ins for every (arch × shape) cell.

No device allocation — these are fed straight to ``jit(...).lower()``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models.model import init_cache, init_params
from repro.train.train_step import init_state

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      microbatches: int = 1) -> Dict[str, Any]:
    """Train batch, with leading microbatch axis (M, B/M, ...)."""
    B, S = shape.global_batch, shape.seq_len
    assert B % microbatches == 0
    mb = B // microbatches
    St = S - cfg.n_patches if cfg.frontend == "vision" else S
    batch = {
        "tokens": SDS((microbatches, mb, St), jnp.int32),
        "labels": SDS((microbatches, mb, S), jnp.int32),
        "mask": SDS((microbatches, mb, S), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = SDS((microbatches, mb, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    St = S - cfg.n_patches if cfg.frontend == "vision" else S
    batch = {"tokens": SDS((B, St), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = SDS((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, Any]:
    """(abstract cache, abstract tokens) for one decode step with a cache of
    ``seq_len`` tokens already resident."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, fill=S - 1))
    tokens = SDS((B,), jnp.int32)
    return cache, tokens


def abstract_state(cfg: ModelConfig, tc: TrainConfig):
    return jax.eval_shape(
        lambda k: init_state(cfg, tc, k), jax.random.key(0))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, tc: TrainConfig = None,
                microbatches: int = 1):
    """The full abstract input tuple for the cell's step function."""
    tc = tc or TrainConfig(microbatches=microbatches)
    if shape.kind == "train":
        return (abstract_state(cfg, tc), train_batch_specs(cfg, shape, tc.microbatches))
    if shape.kind == "prefill":
        return (abstract_params(cfg), prefill_batch_specs(cfg, shape))
    if shape.kind == "decode":
        cache, tokens = decode_inputs(cfg, shape)
        return (abstract_params(cfg), cache, tokens)
    raise ValueError(shape.kind)
