"""End-to-end training driver.

Production path (multi-host): the same loop runs under ``jax.distributed``;
this container exercises it single-process on CPU with reduced configs.

Features: checkpoint/restart (atomic, resumable mid-run), straggler
detection with elastic re-mesh hooks, deterministic data, optional PCSTALL
DVFS telemetry (simulated per-device frequency schedule + energy report).

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 50 --microbatches 2 --dvfs
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import TRAIN_4K, get_config, get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import make_batch
from repro.train import checkpoint as ckpt
from repro.train.elastic import StragglerDetector
from repro.train.train_step import init_state, make_train_step


def train(cfg, tc: TrainConfig, shape: ShapeConfig, *, steps: int,
          resume: bool = True, dvfs: bool = False, log_every: int = 10):
    key = jax.random.key(tc.seed)
    state = init_state(cfg, tc, key)
    start = 0
    if resume:
        try:
            state, start = ckpt.restore(state, tc.checkpoint_dir)
            start += 1
            print(f"[train] resumed from step {start - 1}")
        except FileNotFoundError:
            pass
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    detector = StragglerDetector()
    dvfs_mgr = None
    if dvfs:
        from repro.dvfs_runtime.manager import DVFSManager
        dvfs_mgr = DVFSManager.for_model(cfg, shape)

    losses = []
    for step in range(start, steps):
        t0 = time.perf_counter()
        batch = make_batch(cfg, shape, step, microbatches=tc.microbatches)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        verdict = detector.observe(dt)
        if verdict == "remesh":
            print(f"[elastic] step {step}: persistent straggler — re-mesh "
                  f"requested (see repro.train.elastic.plan_remesh)")
        if dvfs_mgr is not None:
            dvfs_mgr.observe_step(step, dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
        if tc.checkpoint_every and step and step % tc.checkpoint_every == 0:
            path = ckpt.save(state, tc.checkpoint_dir, step)
            print(f"[ckpt] saved {path}")
    ckpt.save(state, tc.checkpoint_dir, steps - 1)
    if dvfs_mgr is not None:
        rep = dvfs_mgr.report()
        print(f"[dvfs] simulated energy {rep['energy_norm']:.3f}x static-1.7, "
              f"accuracy {rep['accuracy']:.3f}")
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--dvfs", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = (ShapeConfig("custom", args.seq, args.batch, "train")
             if args.smoke else TRAIN_4K)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 5),
                     microbatches=args.microbatches,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every,
                     grad_compression=args.grad_compression)
    state, losses = train(cfg, tc, shape, steps=args.steps,
                          resume=not args.no_resume, dvfs=args.dvfs)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
