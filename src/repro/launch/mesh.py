"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else (tests, benches) sees the single real CPU device.

``grid_mesh`` is the process-wide 1-D mesh the sweep layer's grid
executables shard over: built once per (process, device count) and cached,
so a long-lived service dispatching thousands of micro-batches never
re-constructs device meshes on the hot accept path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


@functools.lru_cache(maxsize=None)
def grid_mesh(n_dev: Optional[int] = None) -> Mesh:
    """The single-host 1-D grid mesh over the first ``n_dev`` local devices
    (all of them when ``None``), on the axis name the sweep layer shards
    its flattened (workload x grid-point) operands over. Cached per device
    count for the life of the process — every grid executable family and
    every streaming-service dispatch shares the same Mesh object."""
    devs = jax.local_devices()
    if n_dev is not None:
        devs = devs[:n_dev]
    return Mesh(np.asarray(devs), ("i",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh after pod loss, small test meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def degraded_mesh(lost_pods: int = 1):
    """Elastic fallback: production multi-pod mesh minus ``lost_pods`` pods.
    With 1 of 2 pods lost this collapses to the single-pod mesh."""
    pods = 2 - lost_pods
    if pods <= 1:
        return make_production_mesh(multi_pod=False)
    return jax.make_mesh((pods, 16, 16), ("pod", "data", "model"))
