"""Serving driver: batched prefill + decode loop with KV/SSM-state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --prompt-len 64 --gen 32 --batch 4

The decode loop's per-token greedy sampling lives INSIDE the jitted step
(one dispatch per token; the argmax rides the same executable as the
model math instead of paying an extra un-jitted dispatch between calls),
and with ``--dvfs`` the loop's per-step telemetry streams through the
long-lived :class:`repro.dvfs_runtime.service.DVFSService` — periodic
async report requests overlap decode compute instead of a single fresh
one-shot report after the loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models.model import decode_step, init_cache, init_params, prefill


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          dvfs: bool = False, dvfs_stride: int = 16):
    key = jax.random.key(seed)
    params = init_params(cfg, key)
    St = prompt_len - cfg.n_patches if cfg.frontend == "vision" else prompt_len
    toks = jax.random.randint(key, (batch, St), 0, cfg.vocab)
    pbatch = {"tokens": toks}
    if cfg.frontend == "vision":
        pbatch["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b))

    def _decode_argmax(p, c, t):
        logits, c = decode_step(p, cfg, c, t)
        return jnp.argmax(logits, -1).astype(jnp.int32), c

    decode_fn = jax.jit(_decode_argmax, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits = prefill_fn(params, pbatch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    svc = futs = window = None
    if dvfs:
        from repro.configs.base import ShapeConfig
        from repro.dvfs_runtime.service import DVFSService
        shape = ShapeConfig("serve", prompt_len + gen, batch, "decode")
        svc = DVFSService.for_model(cfg, shape, coalesce_s=0.001)
        futs, window = [], []

    cache = init_cache(cfg, batch, prompt_len + gen, fill=prompt_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    t_prev = t0
    for step in range(gen):
        tok, cache = decode_fn(params, cache, tok)
        out.append(tok)
        if svc is not None:
            # dispatch-cadence telemetry: wall time between async decode
            # dispatches, no extra device syncs on the decode hot loop
            t_now = time.perf_counter()
            window.append((step, t_now - t_prev))
            t_prev = t_now
            if (step + 1) % dvfs_stride == 0 or step == gen - 1:
                # async: the service coalesces + dispatches off-thread,
                # overlapping the remaining decode steps
                futs.append(svc.submit(svc.default_program,
                                       telemetry=window))
                window = []
    jax.block_until_ready(out[-1])
    t_decode = (time.perf_counter() - t0) / gen
    report = {"prefill_s": t_prefill, "decode_s_per_tok": t_decode,
              "tokens": jnp.stack(out, 1)}
    if svc is not None:
        with svc:
            results = [f.result() for f in futs]
        report["dvfs"] = results[-1]["report"]
        report["dvfs_requests"] = len(results)
        report["dvfs_stream"] = svc.stats()
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dvfs", action="store_true")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rep = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
                dvfs=args.dvfs)
    print(f"prefill {rep['prefill_s'] * 1e3:.1f}ms  "
          f"decode {rep['decode_s_per_tok'] * 1e3:.2f}ms/tok  "
          f"out shape {rep['tokens'].shape}")
    if "dvfs" in rep:
        d, s = rep["dvfs"], rep["dvfs_stream"]
        print(f"[dvfs] energy {d['energy_norm']:.3f}x acc {d['accuracy']:.3f}  "
              f"steps {d['step_time']['n_steps']}  "
              f"stream {rep['dvfs_requests']} reqs "
              f"p99 {s['p99_latency_s'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
