"""Serving driver: batched prefill + decode loop with KV/SSM-state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --prompt-len 64 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models.model import decode_step, init_cache, init_params, prefill


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          dvfs: bool = False):
    key = jax.random.key(seed)
    params = init_params(cfg, key)
    St = prompt_len - cfg.n_patches if cfg.frontend == "vision" else prompt_len
    toks = jax.random.randint(key, (batch, St), 0, cfg.vocab)
    pbatch = {"tokens": toks}
    if cfg.frontend == "vision":
        pbatch["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b))
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t),
                        donate_argnums=(1,))

    t0 = time.perf_counter()
    logits = prefill_fn(params, pbatch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    cache = init_cache(cfg, batch, prompt_len + gen, fill=prompt_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen):
        logits, cache = decode_fn(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = (time.perf_counter() - t0) / gen
    report = {"prefill_s": t_prefill, "decode_s_per_tok": t_decode,
              "tokens": jnp.stack(out, 1)}
    if dvfs:
        from repro.configs.base import ShapeConfig
        from repro.dvfs_runtime.manager import DVFSManager
        shape = ShapeConfig("serve", prompt_len + gen, batch, "decode")
        report["dvfs"] = DVFSManager.for_model(cfg, shape).report()
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dvfs", action="store_true")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rep = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
                dvfs=args.dvfs)
    print(f"prefill {rep['prefill_s'] * 1e3:.1f}ms  "
          f"decode {rep['decode_s_per_tok'] * 1e3:.2f}ms/tok  "
          f"out shape {rep['tokens'].shape}")
    if "dvfs" in rep:
        d = rep["dvfs"]
        print(f"[dvfs] energy {d['energy_norm']:.3f}x acc {d['accuracy']:.3f}")


if __name__ == "__main__":
    main()
