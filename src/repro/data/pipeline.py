"""Deterministic sharded synthetic data pipeline.

Production layout: each (step, host) pair derives its shard of the global
batch from a counter-based PRNG — no cross-host coordination, bit-exact
resume after restart from any step (fault tolerance comes for free), and
elastic re-sharding is just re-deriving with a new (n_hosts, host_id).

Token streams are Zipf-ish over the vocab with a Markov phase structure so
losses actually decrease during the integration tests.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def stream_rng(seed: int, i: int) -> np.random.Generator:
    """Element ``i`` of deterministic stream ``seed``, with no sequential
    state: the generator is derived from ``(seed, i)`` alone, so any
    consumer — trace replay, dataset shuffling, split permutation — can
    draw element ``i`` without generating the first ``i - 1``. This is
    the single counter-based contract shared by ``dvfs_request_stream``
    and ``repro.learn`` (training draws and trace replay come from the
    same machinery, per the reproducibility story above)."""
    return np.random.default_rng((seed, i))


def train_val_split(n_items: int, *, val_frac: float = 0.25,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic seeded train/val index split.

    Returns sorted ``(train_idx, val_idx)`` int64 arrays partitioning
    ``range(n_items)``. Counter-based (``stream_rng(seed, n_items)``), so
    the same ``(n_items, val_frac, seed)`` yields the same split on every
    host and process — no RNG state to carry around. Validation gets
    ``round(n_items * val_frac)`` items, at least 1 (and at most
    ``n_items - 1``) whenever ``0 < val_frac`` and ``n_items > 1``."""
    if not 0.0 <= val_frac < 1.0:
        raise ValueError(f"val_frac must be in [0, 1), got {val_frac}")
    perm = stream_rng(seed, n_items).permutation(n_items)
    n_val = int(round(n_items * val_frac))
    if val_frac > 0.0 and n_items > 1:
        n_val = min(max(n_val, 1), n_items - 1)
    return np.sort(perm[n_val:]), np.sort(perm[:n_val])


def export_npz(path, arrays: Dict[str, np.ndarray],
               meta: Optional[dict] = None) -> Path:
    """Deterministic npz export: keys written in sorted order, optional
    ``meta`` dict serialized as canonical (sorted-keys) JSON under the
    ``__meta__`` key. ``np.savez`` stamps fixed zip timestamps, so the
    same payload produces a bitwise-identical file — the property the
    dataset-determinism tests assert."""
    out = {k: np.ascontiguousarray(arrays[k]) for k in sorted(arrays)}
    if meta is not None:
        blob = json.dumps(meta, sort_keys=True).encode("utf-8")
        out["__meta__"] = np.frombuffer(blob, dtype=np.uint8)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **out)
    return path


def load_npz(path) -> Tuple[Dict[str, np.ndarray], Optional[dict]]:
    """Inverse of :func:`export_npz`: ``(arrays, meta_or_None)``."""
    with np.load(path) as f:
        arrays = {k: f[k] for k in f.files if k != "__meta__"}
        meta = (json.loads(f["__meta__"].tobytes().decode("utf-8"))
                if "__meta__" in f.files else None)
    return arrays, meta


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    n_phases: int = 8


def _batch_tokens(key: jax.Array, batch: int, seq: int, vocab: int,
                  dc: DataConfig) -> jax.Array:
    """Synthetic but learnable: per-sequence phase picks a distinct band of
    the vocab; within a phase, tokens follow t_{i+1} = (a*t_i + b) % band
    with noise — next-token prediction is learnable to well below ln(V)."""
    k1, k2, k3 = jax.random.split(key, 3)
    band = max(vocab // dc.n_phases, 16)
    phase = jax.random.randint(k1, (batch, 1), 0, dc.n_phases)
    base = phase * (vocab // dc.n_phases)
    x0 = jax.random.randint(k2, (batch, 1), 0, band)
    a, b = 31, 17
    idx = jnp.arange(seq)[None, :]
    # affine progression within band + occasional jumps
    tok = (x0 * (a ** (idx % 7)) + b * idx) % band
    noise = jax.random.bernoulli(k3, 0.05, (batch, seq))
    rand = jax.random.randint(k3, (batch, seq), 0, band)
    tok = jnp.where(noise, rand, tok)
    return (base + tok).astype(jnp.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               *, microbatches: int = 1, host_id: int = 0, n_hosts: int = 1,
               dc: DataConfig = DataConfig()) -> Dict[str, jax.Array]:
    """Global batch for ``step`` (host-sharded slice if n_hosts > 1)."""
    B = shape.global_batch // n_hosts
    S = shape.seq_len
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.key(dc.seed), step), host_id)
    St = S - cfg.n_patches if cfg.frontend == "vision" else S
    toks = _batch_tokens(key, B, St + 1, cfg.vocab, dc)
    tokens, labels_t = toks[:, :-1], toks[:, 1:]
    if cfg.frontend == "vision":
        pe = jax.random.normal(jax.random.fold_in(key, 7),
                               (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        labels = jnp.concatenate(
            [jnp.zeros((B, cfg.n_patches), jnp.int32), labels_t], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.n_patches), jnp.int32),
             jnp.ones((B, St), jnp.int32)], axis=1)
        batch = {"tokens": tokens, "labels": labels, "mask": mask,
                 "patch_embeds": pe}
    else:
        batch = {"tokens": tokens, "labels": labels_t,
                 "mask": jnp.ones((B, St), jnp.int32)}
    if microbatches > 1:
        batch = {k: v.reshape(microbatches, B // microbatches, *v.shape[1:])
                 for k, v in batch.items()}
    else:
        batch = {k: v[None] for k, v in batch.items()}
    return batch


def data_iterator(cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
                  **kw) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, **kw)
        step += 1


def dvfs_request_stream(n_requests: int, *, seed: int = 0,
                        workloads: Sequence[str] = ("comd", "xsbench",
                                                    "lulesh", "minife"),
                        epoch_us: Sequence[float] = (1.0, 10.0),
                        objectives: Sequence[str] = ("ed2p",),
                        steps_per_request: int = 4,
                        ) -> Iterator[Tuple["Program", dict, tuple]]:
    """Trace-driven request stream for the streaming DVFS service.

    Same counter-based contract as the token pipeline: request ``i`` is
    derived from ``(seed, i)`` alone, so benches and tests replay
    bit-identical streams with no stored trace files. Yields ``(program,
    axes_overrides, telemetry)`` tuples ready for ``DVFSService.submit`` —
    a workload phase program, a traced-axis operating point drawn from
    ``epoch_us`` x ``objectives``, and a plausible (step, seconds)
    step-time window."""
    from repro.core.workloads import get_workload
    names = tuple(workloads)
    progs = {n: get_workload(n) for n in names}
    for i in range(n_requests):
        rng = stream_rng(seed, i)
        name = names[int(rng.integers(len(names)))]
        axes = {"epoch_us": float(epoch_us[int(rng.integers(len(epoch_us)))]),
                "objective": objectives[int(rng.integers(len(objectives)))]}
        telemetry = tuple(
            (i * steps_per_request + s, float(rng.gamma(2.0, 0.005)))
            for s in range(steps_per_request))
        yield progs[name], axes, telemetry
