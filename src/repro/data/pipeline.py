"""Deterministic sharded synthetic data pipeline.

Production layout: each (step, host) pair derives its shard of the global
batch from a counter-based PRNG — no cross-host coordination, bit-exact
resume after restart from any step (fault tolerance comes for free), and
elastic re-sharding is just re-deriving with a new (n_hosts, host_id).

Token streams are Zipf-ish over the vocab with a Markov phase structure so
losses actually decrease during the integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    n_phases: int = 8


def _batch_tokens(key: jax.Array, batch: int, seq: int, vocab: int,
                  dc: DataConfig) -> jax.Array:
    """Synthetic but learnable: per-sequence phase picks a distinct band of
    the vocab; within a phase, tokens follow t_{i+1} = (a*t_i + b) % band
    with noise — next-token prediction is learnable to well below ln(V)."""
    k1, k2, k3 = jax.random.split(key, 3)
    band = max(vocab // dc.n_phases, 16)
    phase = jax.random.randint(k1, (batch, 1), 0, dc.n_phases)
    base = phase * (vocab // dc.n_phases)
    x0 = jax.random.randint(k2, (batch, 1), 0, band)
    a, b = 31, 17
    idx = jnp.arange(seq)[None, :]
    # affine progression within band + occasional jumps
    tok = (x0 * (a ** (idx % 7)) + b * idx) % band
    noise = jax.random.bernoulli(k3, 0.05, (batch, seq))
    rand = jax.random.randint(k3, (batch, seq), 0, band)
    tok = jnp.where(noise, rand, tok)
    return (base + tok).astype(jnp.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               *, microbatches: int = 1, host_id: int = 0, n_hosts: int = 1,
               dc: DataConfig = DataConfig()) -> Dict[str, jax.Array]:
    """Global batch for ``step`` (host-sharded slice if n_hosts > 1)."""
    B = shape.global_batch // n_hosts
    S = shape.seq_len
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.key(dc.seed), step), host_id)
    St = S - cfg.n_patches if cfg.frontend == "vision" else S
    toks = _batch_tokens(key, B, St + 1, cfg.vocab, dc)
    tokens, labels_t = toks[:, :-1], toks[:, 1:]
    if cfg.frontend == "vision":
        pe = jax.random.normal(jax.random.fold_in(key, 7),
                               (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        labels = jnp.concatenate(
            [jnp.zeros((B, cfg.n_patches), jnp.int32), labels_t], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.n_patches), jnp.int32),
             jnp.ones((B, St), jnp.int32)], axis=1)
        batch = {"tokens": tokens, "labels": labels, "mask": mask,
                 "patch_embeds": pe}
    else:
        batch = {"tokens": tokens, "labels": labels_t,
                 "mask": jnp.ones((B, St), jnp.int32)}
    if microbatches > 1:
        batch = {k: v.reshape(microbatches, B // microbatches, *v.shape[1:])
                 for k, v in batch.items()}
    else:
        batch = {k: v[None] for k, v in batch.items()}
    return batch


def data_iterator(cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
                  **kw) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, shape, step, **kw)
        step += 1


def dvfs_request_stream(n_requests: int, *, seed: int = 0,
                        workloads: Sequence[str] = ("comd", "xsbench",
                                                    "lulesh", "minife"),
                        epoch_us: Sequence[float] = (1.0, 10.0),
                        objectives: Sequence[str] = ("ed2p",),
                        steps_per_request: int = 4,
                        ) -> Iterator[Tuple["Program", dict, tuple]]:
    """Trace-driven request stream for the streaming DVFS service.

    Same counter-based contract as the token pipeline: request ``i`` is
    derived from ``(seed, i)`` alone, so benches and tests replay
    bit-identical streams with no stored trace files. Yields ``(program,
    axes_overrides, telemetry)`` tuples ready for ``DVFSService.submit`` —
    a workload phase program, a traced-axis operating point drawn from
    ``epoch_us`` x ``objectives``, and a plausible (step, seconds)
    step-time window."""
    from repro.core.workloads import get_workload
    names = tuple(workloads)
    progs = {n: get_workload(n) for n in names}
    for i in range(n_requests):
        rng = np.random.default_rng((seed, i))
        name = names[int(rng.integers(len(names)))]
        axes = {"epoch_us": float(epoch_us[int(rng.integers(len(epoch_us)))]),
                "objective": objectives[int(rng.integers(len(objectives)))]}
        telemetry = tuple(
            (i * steps_per_request + s, float(rng.gamma(2.0, 0.005)))
            for s in range(steps_per_request))
        yield progs[name], axes, telemetry
