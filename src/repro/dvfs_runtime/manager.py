"""DVFS manager: PCSTALL-driven per-device frequency scheduling for a
training/serving job (simulated — TPUs expose no user DVFS today, so this
reports what the paper's mechanism would buy on this workload's phase
structure). Reports dispatch through the device-sharded grid sweep layer
(``repro.core.sweep.run_grid``): a single report is a 1-point grid, and
``grid_report`` evaluates a whole epoch-granularity x objective grid in
one executable family."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import mechanisms as MECH
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import SimConfig, ednp, prediction_accuracy
from repro.core.sweep import run_grid
from repro.core.workloads import Program
from repro.dvfs_runtime.telemetry import arch_program

Mechanism = Union[str, MechanismSpec]


@dataclasses.dataclass
class DVFSManager:
    program: Program
    sim: SimConfig
    # the mechanism this deployment evaluates and the baseline its metrics
    # normalize to — any registered MechanismSpec (or name), so a custom
    # registered predictor can be managed without touching this module
    mechanism: Mechanism = "pcstall"
    baseline: Mechanism = "static17"
    step_times: list = dataclasses.field(default_factory=list)

    @classmethod
    def for_model(cls, cfg: ModelConfig, shape: ShapeConfig,
                  objective: str = "ed2p", n_cu: int = 16,
                  mechanism: Mechanism = "pcstall",
                  baseline: Mechanism = "static17") -> "DVFSManager":
        prog = arch_program(cfg, shape)
        sim = SimConfig(n_cu=n_cu, n_epochs=400, objective=objective)
        return cls(program=prog, sim=sim, mechanism=mechanism,
                   baseline=baseline)

    def observe_step(self, step: int, seconds: float) -> None:
        self.step_times.append(seconds)

    def _mechs(self, baseline: Optional[Mechanism]):
        """(baseline_spec, mechanism_spec) for one report, resolved
        through the registry (``baseline=None`` = the manager default)."""
        base = MECH.resolve(self.baseline if baseline is None else baseline)
        return base, MECH.resolve(self.mechanism)

    def _point_report(self, traces: Dict, epoch_us: float,
                      base_spec: MechanismSpec,
                      mech_spec: MechanismSpec) -> Dict[str, float]:
        base, tr = traces[base_spec.name], traces[mech_spec.name]
        budget = 0.9 * base["work"].sum()
        E0, D0, M0 = ednp(base, budget, epoch_us)
        E, D, M = ednp(tr, budget, epoch_us)
        # one bin per V/f state of THIS job's ladder (n_freqs, the static
        # half of the power regime — not the module-default constant): a
        # non-default ladder must not silently truncate or mislabel
        # freq_timeshare
        h = np.bincount(tr["fidx"].ravel(),
                        minlength=self.sim.power.n_freqs) / tr["fidx"].size
        return {
            # a static mechanism never predicts (its trace carries err==0),
            # so accuracy is undefined — match suite_metrics' NaN
            "accuracy": prediction_accuracy(tr)
            if mech_spec.family != "static" else float("nan"),
            "energy_norm": E / E0,
            "delay_norm": D / D0,
            "ed2p_norm": M / M0,
            "freq_timeshare": [round(float(x), 3) for x in h],
            "mean_step_s": float(np.mean(self.step_times)) if self.step_times else 0.0,
        }

    def report(self, baseline: Optional[Mechanism] = None
               ) -> Dict[str, float]:
        """Run the managed mechanism against ``baseline`` (default the
        manager's, normally static-1.7) on this job's phase program (a
        1-point grid dispatch; jit-cached across repeated reports)."""
        base_spec, mech_spec = self._mechs(baseline)
        grid = run_grid([self.program], self.sim,
                        {"objective": [self.sim.objective]},
                        (base_spec, mech_spec))
        trs = grid[(self.sim.objective,)][self.program.name]
        return self._point_report(trs, self.sim.epoch_us, base_spec,
                                  mech_spec)

    def grid_report(self, epoch_us: Sequence[float] = (1.0, 10.0),
                    objectives: Optional[Sequence[str]] = None,
                    baseline: Optional[Mechanism] = None
                    ) -> Dict[tuple, Dict[str, float]]:
        """Sweep epoch granularity x objective for this job in ONE grid
        executable family (what a deployment would use to pick its DVFS
        operating point). Returns ``{(epoch_us, objective): report}``."""
        objectives = [self.sim.objective] if objectives is None \
            else list(objectives)
        base_spec, mech_spec = self._mechs(baseline)
        grid = run_grid([self.program], self.sim,
                        {"epoch_us": list(epoch_us), "objective": objectives},
                        (base_spec, mech_spec))
        return {key: self._point_report(trs[self.program.name], key[0],
                                        base_spec, mech_spec)
                for key, trs in grid.items()}
