"""DVFS manager: PCSTALL-driven per-device frequency scheduling for a
training/serving job (simulated — TPUs expose no user DVFS today, so this
reports what the paper's mechanism would buy on this workload's phase
structure). Reports are thin clients of the sweep layer's
``repro.core.sweep.GridExecutor``: the manager holds one executor per
(baseline, mechanism) pair — the same compiled-family handle the streaming
``repro.dvfs_runtime.service.DVFSService`` is built on — so a single
``report`` is a 1-job dispatch and ``grid_report`` evaluates a whole
epoch-granularity x objective grid as one micro-batch, all through the
same executables ``run_grid`` compiles (bitwise-equal rows, shared jit
cache)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import mechanisms as MECH
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import SimConfig, ednp, prediction_accuracy
from repro.core.sweep import GridExecutor
from repro.core.workloads import Program
from repro.dvfs_runtime.telemetry import arch_program

Mechanism = Union[str, MechanismSpec]

StepLog = Sequence[Tuple[int, float]]


def step_time_stats(step_log: StepLog) -> Dict[str, float]:
    """Summarize observed (step, seconds) telemetry pairs: count, mean and
    p50/p99 step seconds, plus the observed step span (steps need not be
    contiguous — a decode loop may only sample every K-th token)."""
    if not step_log:
        return {"n_steps": 0, "mean_step_s": 0.0, "p50_step_s": 0.0,
                "p99_step_s": 0.0, "first_step": -1, "last_step": -1}
    steps = [int(s) for s, _ in step_log]
    secs = np.asarray([t for _, t in step_log], np.float64)
    return {"n_steps": int(secs.size),
            "mean_step_s": float(secs.mean()),
            "p50_step_s": float(np.percentile(secs, 50)),
            "p99_step_s": float(np.percentile(secs, 99)),
            "first_step": min(steps), "last_step": max(steps)}


def point_report(traces: Dict, epoch_us: float, base_spec: MechanismSpec,
                 mech_spec: MechanismSpec, n_freqs: int,
                 step_log: StepLog = ()) -> Dict[str, float]:
    """One job's DVFS report from its ``{mechanism: trace}`` dict: ED^2P /
    energy / delay vs the baseline, the V/f residency histogram, and the
    observed step-time stats. Shared by the manager's reports and the
    streaming service's per-request reports (so both speak one schema)."""
    base, tr = traces[base_spec.name], traces[mech_spec.name]
    budget = 0.9 * base["work"].sum()
    E0, D0, M0 = ednp(base, budget, epoch_us)
    E, D, M = ednp(tr, budget, epoch_us)
    # one bin per V/f state of THIS job's ladder (n_freqs, the static
    # half of the power regime — not the module-default constant): a
    # non-default ladder must not silently truncate or mislabel
    # freq_timeshare
    h = np.bincount(tr["fidx"].ravel(), minlength=n_freqs) / tr["fidx"].size
    stats = step_time_stats(step_log)
    return {
        # a static mechanism never predicts (its trace carries err==0),
        # so accuracy is undefined — match suite_metrics' NaN
        "accuracy": prediction_accuracy(tr)
        if mech_spec.family != "static" else float("nan"),
        "energy_norm": E / E0,
        "delay_norm": D / D0,
        "ed2p_norm": M / M0,
        "freq_timeshare": [round(float(x), 3) for x in h],
        "mean_step_s": stats["mean_step_s"],  # back-compat alias
        "step_time": stats,
    }


@dataclasses.dataclass
class DVFSManager:
    program: Program
    sim: SimConfig
    # the mechanism this deployment evaluates and the baseline its metrics
    # normalize to — any registered MechanismSpec (or name), so a custom
    # registered predictor can be managed without touching this module
    mechanism: Mechanism = "pcstall"
    baseline: Mechanism = "static17"
    # observed (step, seconds) telemetry pairs (``observe_step``)
    step_log: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)
    _executors: Dict[tuple, GridExecutor] = dataclasses.field(
        default_factory=dict, repr=False)

    @classmethod
    def for_model(cls, cfg: ModelConfig, shape: ShapeConfig,
                  objective: str = "ed2p", n_cu: int = 16,
                  mechanism: Mechanism = "pcstall",
                  baseline: Mechanism = "static17") -> "DVFSManager":
        prog = arch_program(cfg, shape)
        sim = SimConfig(n_cu=n_cu, n_epochs=400, objective=objective)
        return cls(program=prog, sim=sim, mechanism=mechanism,
                   baseline=baseline)

    def observe_step(self, step: int, seconds: float) -> None:
        self.step_log.append((int(step), float(seconds)))

    def _mechs(self, baseline: Optional[Mechanism]):
        """(baseline_spec, mechanism_spec) for one report, resolved
        through the registry (``baseline=None`` = the manager default)."""
        base = MECH.resolve(self.baseline if baseline is None else baseline)
        return base, MECH.resolve(self.mechanism)

    def _executor(self, base_spec: MechanismSpec,
                  mech_spec: MechanismSpec) -> GridExecutor:
        """The jit-family handle for one (baseline, mechanism) pair —
        built once and reused by every subsequent report, so repeated
        reports dispatch cached executables (and, because an exact-size
        1-job batch lays out operands exactly like a 1-point ``run_grid``,
        the executables are shared with the sweep layer's own cache)."""
        key = (base_spec.name, mech_spec.name)
        if key not in self._executors:
            self._executors[key] = GridExecutor(
                self.sim, (base_spec, mech_spec),
                p_max=self.program.n_blocks)
        return self._executors[key]

    def _point_report(self, traces: Dict, epoch_us: float,
                      base_spec: MechanismSpec,
                      mech_spec: MechanismSpec) -> Dict[str, float]:
        return point_report(traces, epoch_us, base_spec, mech_spec,
                            self.sim.power.n_freqs, self.step_log)

    def report(self, baseline: Optional[Mechanism] = None
               ) -> Dict[str, float]:
        """Run the managed mechanism against ``baseline`` (default the
        manager's, normally static-1.7) on this job's phase program (a
        1-job executor dispatch; jit-cached across repeated reports)."""
        base_spec, mech_spec = self._mechs(baseline)
        trs = self._executor(base_spec, mech_spec).run(
            [(self.program, {"objective": self.sim.objective})])[0]
        return self._point_report(trs, self.sim.epoch_us, base_spec,
                                  mech_spec)

    def grid_report(self, epoch_us: Sequence[float] = (1.0, 10.0),
                    objectives: Optional[Sequence[str]] = None,
                    baseline: Optional[Mechanism] = None
                    ) -> Dict[tuple, Dict[str, float]]:
        """Sweep epoch granularity x objective for this job as ONE
        executor micro-batch (what a deployment would use to pick its
        DVFS operating point). Returns ``{(epoch_us, objective): report}``."""
        objectives = [self.sim.objective] if objectives is None \
            else list(objectives)
        base_spec, mech_spec = self._mechs(baseline)
        points = [{"epoch_us": float(e), "objective": o}
                  for e in epoch_us for o in objectives]
        res = self._executor(base_spec, mech_spec).run(
            [(self.program, p) for p in points])
        return {(p["epoch_us"], p["objective"]):
                self._point_report(tr, p["epoch_us"], base_spec, mech_spec)
                for p, tr in zip(points, res)}
