"""DVFS manager: PCSTALL-driven per-device frequency scheduling for a
training/serving job (simulated — TPUs expose no user DVFS today, so this
reports what the paper's mechanism would buy on this workload's phase
structure)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.simulate import SimConfig, ednp, prediction_accuracy
from repro.core.sweep import run_suite
from repro.core.workloads import Program
from repro.dvfs_runtime.telemetry import arch_program


@dataclasses.dataclass
class DVFSManager:
    program: Program
    sim: SimConfig
    step_times: list = dataclasses.field(default_factory=list)

    @classmethod
    def for_model(cls, cfg: ModelConfig, shape: ShapeConfig,
                  objective: str = "ed2p", n_cu: int = 16) -> "DVFSManager":
        prog = arch_program(cfg, shape)
        sim = SimConfig(n_cu=n_cu, n_epochs=400, objective=objective)
        return cls(program=prog, sim=sim)

    def observe_step(self, step: int, seconds: float) -> None:
        self.step_times.append(seconds)

    def report(self) -> Dict[str, float]:
        """Run PCSTALL vs static-1.7 on this job's phase program (one
        batched suite dispatch; jit-cached across repeated reports)."""
        traces = run_suite([self.program], self.sim, ("static17", "pcstall"))
        trs = traces[self.program.name]
        base, tr = trs["static17"], trs["pcstall"]
        budget = 0.9 * base["work"].sum()
        E0, D0, M0 = ednp(base, budget, self.sim.epoch_us)
        E, D, M = ednp(tr, budget, self.sim.epoch_us)
        h = np.bincount(tr["fidx"].ravel(), minlength=10) / tr["fidx"].size
        return {
            "accuracy": prediction_accuracy(tr),
            "energy_norm": E / E0,
            "delay_norm": D / D0,
            "ed2p_norm": M / M0,
            "freq_timeshare": [round(float(x), 3) for x in h],
            "mean_step_s": float(np.mean(self.step_times)) if self.step_times else 0.0,
        }
