"""Streaming DVFS service: async micro-batched grid dispatch with
double-buffered donated carries.

The paper's premise makes fine-grain DVFS a *continuous* control problem;
at fleet scale the controller is a long-lived process absorbing a stream
of (job, telemetry) requests — the deadline-aware datacenter setting of
Ilager et al. (arXiv:2004.08177) is the canonical consumer. This module
turns the sweep substrate into that service:

* ``submit`` never blocks on the device: a request enqueues and resolves
  through a ``concurrent.futures.Future``;
* a dispatcher thread coalesces queued requests into micro-batches
  (up to ``max_batch`` jobs within a ``coalesce_s`` window), pads each
  batch to one of the executor's static shape ``buckets`` and dispatches
  it through the SAME shard_map'd grid executables ``run_grid`` compiles
  — so the whole stream is served by at most one compile per family
  (<= 2 fork-family compiles with the default single bucket; the
  ``run_grid`` no-retrace contract carried over to streaming) and every
  streamed row is bitwise-equal to the one-shot grid answer — at every
  batch size, including singletons (the executor floors dispatches at 2
  rows), and under either engine (a ``use_pallas="v2"`` config streams
  the fused-kernel grid engine and stays bitwise vs the one-shot v2
  grid);
* double buffering: a depth-``depth`` semaphore bounds in-flight batches,
  so batch N+1's operand staging, host->device ``jax.device_put`` and
  donated-carry build overlap batch N's compute — dispatch itself never
  calls ``block_until_ready``;
* a collector thread alone synchronizes: it harvests finished batches in
  dispatch order, cuts them into per-job traces, attaches manager-schema
  reports (``repro.dvfs_runtime.manager.point_report``) and resolves the
  futures.

``stats()`` reports sustained jobs/sec and dispatch-latency percentiles —
the ``serve_stream`` benchmark record is built from exactly these
counters.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import mechanisms as MECH
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import SimConfig
from repro.core.sweep import GridExecutor, PendingGrid
from repro.core.workloads import Program
from repro.dvfs_runtime.manager import StepLog, point_report
from repro.dvfs_runtime.telemetry import arch_program

Mechanism = Union[str, MechanismSpec]

_SHUTDOWN = object()


@dataclasses.dataclass
class _Request:
    program: Program
    axes: dict
    telemetry: Tuple[Tuple[int, float], ...]
    future: Future
    t_submit: float


class DVFSService:
    """A long-lived streaming front-end over one :class:`GridExecutor`.

    ``submit(program, axes, telemetry)`` returns a Future immediately; its
    result is ``{"traces", "report", "latency_s", "batch_size"}`` where
    ``traces`` is the job's ``{mechanism: trace}`` dict (bitwise-equal to
    a one-shot ``run_grid`` over the same job) and ``report`` is the
    manager-schema point report against the service baseline, including
    the request's own step-time telemetry stats.

    Shape-bucketing knobs: ``buckets`` is the set of static micro-batch
    shapes (default a single bucket of ``max_batch`` — one compile per
    family for the life of the process); ``coalesce_s`` is how long the
    dispatcher waits to fill a batch before dispatching short; ``depth``
    is the number of in-flight batches (2 = double buffering).
    """

    def __init__(self, static_cfg: SimConfig,
                 mechanism: Mechanism = "pcstall",
                 baseline: Mechanism = "static17", *,
                 max_batch: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 coalesce_s: float = 0.002,
                 depth: int = 2,
                 p_max: int = 1024,
                 n_dev: Optional[int] = None,
                 with_reports: bool = True):
        assert depth >= 1
        self.static_cfg = static_cfg
        self.baseline = MECH.resolve(baseline)
        self.mechanism = MECH.resolve(mechanism)
        specs = [self.baseline]
        if self.mechanism.name != self.baseline.name:
            specs.append(self.mechanism)
        if buckets is None:
            buckets = (max_batch,)
        self.executor = GridExecutor(static_cfg, specs, p_max=p_max,
                                     buckets=buckets, n_dev=n_dev)
        self.coalesce_s = coalesce_s
        self.depth = depth
        self.with_reports = with_reports

        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._done: "queue.SimpleQueue" = queue.SimpleQueue()
        self._inflight = threading.BoundedSemaphore(depth)
        self._lock = threading.Lock()
        self._lat: list = []
        self._batch_sizes: list = []
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._closed = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="dvfs-dispatch", daemon=True)
        self._collector = threading.Thread(
            target=self._collect_loop, name="dvfs-collect", daemon=True)
        self._dispatcher.start()
        self._collector.start()

    @classmethod
    def for_model(cls, cfg: ModelConfig, shape: ShapeConfig,
                  objective: str = "ed2p", n_cu: int = 16,
                  **kw) -> "DVFSService":
        """Service sized like ``DVFSManager.for_model`` — same SimConfig,
        so a decode loop's requests ride the manager's numerics."""
        sim = SimConfig(n_cu=n_cu, n_epochs=400, objective=objective)
        svc = cls(sim, **kw)
        svc.default_program = arch_program(cfg, shape)
        return svc

    # ------------------------------------------------------------------
    # accept loop
    # ------------------------------------------------------------------

    def submit(self, program: Program, axes: Optional[dict] = None,
               telemetry: StepLog = ()) -> Future:
        """Enqueue one (job, telemetry) request. Never blocks on the
        device — returns a Future resolved by the collector thread."""
        fut: Future = Future()
        now = time.perf_counter()
        # the closed check and the enqueue share the lock with close() so
        # no request can slip in behind the shutdown token unresolved
        with self._lock:
            if self._closed:
                raise RuntimeError("DVFSService is closed")
            if self._t_first is None:
                self._t_first = now
            self._q.put(_Request(
                program, dict(axes or {}),
                tuple((int(s), float(t)) for s, t in telemetry), fut, now))
        return fut

    def map(self, requests: Iterable[tuple]) -> list:
        """Submit a whole request iterable, then gather results in order.
        Each request is ``(program, axes)`` or ``(program, axes,
        telemetry)``. Blocks only on the gather."""
        futs = [self.submit(*r) for r in requests]
        return [f.result() for f in futs]

    # ------------------------------------------------------------------
    # worker threads
    # ------------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        max_batch = self.executor.max_batch
        while True:
            req = self._q.get()
            if req is _SHUTDOWN:
                self._done.put(_SHUTDOWN)
                return
            batch = [req]
            stop = False
            deadline = time.perf_counter() + self.coalesce_s
            while max_batch is None or len(batch) < max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _SHUTDOWN:
                    stop = True
                    break
                batch.append(nxt)
            # double buffering: at most `depth` dispatched batches alive —
            # this acquire is the ONLY backpressure, and it waits on the
            # collector (host-side), never on the device directly
            self._inflight.acquire()
            try:
                pending = self.executor.dispatch(
                    [(r.program, r.axes) for r in batch])
            except Exception as e:  # bad request: fail the batch, move on
                self._inflight.release()
                for r in batch:
                    r.future.set_exception(e)
            else:
                self._done.put((pending, batch))
            if stop:
                self._done.put(_SHUTDOWN)
                return

    def _collect_loop(self) -> None:
        while True:
            item = self._done.get()
            if item is _SHUTDOWN:
                return
            pending, batch = item
            pending: PendingGrid
            try:
                traces = pending.block_until_ready().traces()
            except Exception as e:
                for r in batch:
                    r.future.set_exception(e)
                self._inflight.release()
                continue
            self._inflight.release()
            t_done = time.perf_counter()
            lats = [t_done - r.t_submit for r in batch]
            with self._lock:
                self._lat.extend(lats)
                self._batch_sizes.append(len(batch))
                self._t_last = t_done
            for r, trs, lat in zip(batch, traces, lats):
                res = {"traces": trs, "latency_s": lat,
                       "batch_size": len(batch)}
                if self.with_reports:
                    epoch_us = float(r.axes.get(
                        "epoch_us", self.static_cfg.epoch_us))
                    res["report"] = point_report(
                        trs, epoch_us, self.baseline, self.mechanism,
                        self.static_cfg.power.n_freqs, r.telemetry)
                r.future.set_result(res)

    # ------------------------------------------------------------------
    # stats / lifecycle
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Sustained throughput + dispatch-latency percentiles over every
        job resolved so far (latency = submit -> result ready)."""
        with self._lock:
            lat = np.asarray(self._lat, np.float64)
            sizes = list(self._batch_sizes)
            wall = (self._t_last - self._t_first) \
                if (self._t_first is not None and self._t_last is not None) \
                else 0.0
        n = int(lat.size)
        return {
            "jobs": n,
            "batches": len(sizes),
            "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
            "wall_s": wall,
            "jobs_per_sec": n / wall if wall > 0 else 0.0,
            "p50_latency_s": float(np.percentile(lat, 50)) if n else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if n else 0.0,
            "max_latency_s": float(lat.max()) if n else 0.0,
        }

    def reset_stats(self) -> None:
        """Zero the throughput/latency counters (keep the compiled
        executables): benchmarks warm the service, reset, then measure
        steady-state only."""
        with self._lock:
            self._lat.clear()
            self._batch_sizes.clear()
            self._t_first = self._t_last = None

    def close(self) -> None:
        """Drain: everything submitted before ``close`` still resolves
        (FIFO ahead of the shutdown token), then both threads exit."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._q.put(_SHUTDOWN)
        self._dispatcher.join()
        self._collector.join()

    def __enter__(self) -> "DVFSService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
