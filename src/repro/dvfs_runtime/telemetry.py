"""Arch-derived workload traces: map a model's train/serve step onto a
PCSTALL program (the beyond-paper TPU integration, DESIGN.md §3).

Each op in the step program becomes a run of PC blocks whose frequency
sensitivity comes from its arithmetic intensity relative to the TPU ridge
point (peak_flops / hbm_bw ~ 240 flops/byte on v5e): compute-bound ops
scale with core frequency, HBM-bound ops don't (the `s_waitcnt` analogue
is DMA wait). Collectives map to near-zero-sensitivity "async" blocks.

The resulting Program plugs straight into repro.core.simulate — PCSTALL
predicts the per-device phase schedule of the training step, which is
*exactly* the paper's insight transplanted: a training step is a small,
iteratively re-executed program, so a PC-indexed table converges within a
handful of steps.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.workloads import Program, _finalize
from repro.roofline.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

RIDGE = PEAK_FLOPS / HBM_BW  # flops/byte


def _op(name: str, flops: float, bytes_: float, coll_bytes: float = 0.0):
    return (name, flops, bytes_, coll_bytes)


def step_ops(cfg: ModelConfig, shape: ShapeConfig) -> List[Tuple[str, float, float, float]]:
    """Analytic (flops, hbm bytes, collective bytes) per op class for one
    step of this (arch x shape) cell, whole-model (per layer x L)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        S_ctx, S = shape.seq_len, 1
    else:
        S_ctx = S
    T = B * S  # tokens touched this step
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    H, Hkv = max(cfg.n_heads, 1), max(cfg.n_kv_heads, 1)
    bt = 2  # bf16
    ops: List[Tuple[str, float, float, float]] = []
    train_mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd

    if cfg.attn_kind != "none":
        qkv_p = d * (H + 2 * Hkv) * hd
        ops.append(_op("qkv_proj", 2 * T * qkv_p * train_mult * L,
                       (qkv_p * bt + T * d * bt) * L, 0))
        eff_ctx = min(cfg.window, S_ctx) if cfg.attn_kind == "swa" else S_ctx
        attn_f = 4 * T * eff_ctx * H * hd * train_mult * L
        attn_b = (T * H * hd * bt + B * eff_ctx * Hkv * hd * 2 * bt) * L
        ops.append(_op("attention", attn_f, attn_b, 0))
        o_p = H * hd * d
        ops.append(_op("o_proj", 2 * T * o_p * train_mult * L,
                       (o_p * bt + T * d * bt) * L, 0))
    if cfg.family in ("ssm", "hybrid"):
        n = cfg.ssm.state_size if cfg.ssm else 16
        ssm_f = T * d * n * 8 * train_mult * L
        ops.append(_op("ssm_scan", ssm_f, (T * d * bt * 3 + d * d * bt) * L, 0))
        ops.append(_op("mix_proj", 2 * T * 4 * d * d * train_mult * L,
                       4 * d * d * bt * L, 0))
    if cfg.moe is not None:
        e = cfg.moe
        ef = 2 * T * e.top_k * 3 * d * e.expert_d_ff * train_mult * L
        ew = e.num_experts * 3 * d * e.expert_d_ff * bt * L
        # all-to-all dispatch+combine over the EP axis
        a2a = 2 * T * d * bt * L
        ops.append(_op("moe_ffn", ef, ew + T * d * bt * L, 0))
        ops.append(_op("moe_a2a", T * d * 0.1, T * d * bt * L, a2a))
        if e.num_shared:
            fs = e.num_shared * (e.shared_d_ff or e.expert_d_ff)
            ops.append(_op("shared_ffn", 2 * T * 3 * d * fs * train_mult * L,
                           3 * d * fs * bt * L, 0))
    else:
        ops.append(_op("ffn", 2 * T * 3 * d * cfg.d_ff * train_mult * L,
                       (3 * d * cfg.d_ff * bt + T * d * bt) * L, 0))
    ops.append(_op("norms_rope", T * d * 20 * L, T * d * bt * 4 * L, 0))
    ops.append(_op("logits", 2 * T * d * cfg.vocab * train_mult,
                   cfg.vocab * d * bt + T * cfg.vocab * 4, 0))
    if shape.kind == "train":
        # gradient reduce-scatter/all-gather over DP axes
        pbytes = cfg.n_params * 4
        ops.append(_op("grad_reduce", pbytes * 0.01, pbytes, pbytes))
        ops.append(_op("optimizer", cfg.n_params * 8, cfg.n_params * 16, 0))
    return ops


def arch_program(cfg: ModelConfig, shape: ShapeConfig, n_blocks: int = 1024,
                 chips: int = 256) -> Program:
    """Compile the step op list into a PCSTALL Program: block counts by op
    time share; sensitivity by arithmetic intensity."""
    ops = step_ops(cfg, shape)
    times, core_shares, mem_fracs = [], [], []
    for name, f, b, cb in ops:
        t_comp = f / (chips * PEAK_FLOPS)
        t_mem = b / (chips * HBM_BW)
        t_coll = cb / (chips * ICI_BW)
        t = max(t_comp, t_mem, t_coll, 1e-12)
        times.append(t)
        ai = f / max(b, 1.0)
        core = float(ai / (ai + RIDGE))
        if t_coll == t:  # collective-bound: async, frequency-insensitive
            core *= 0.1
        core_shares.append(core)
        mem_fracs.append(min(max(t_mem, t_coll) / t, 1.0))
    times = np.asarray(times)
    shares = times / times.sum()
    i0 = np.zeros(n_blocks)
    sens = np.zeros(n_blocks)
    mem = np.zeros(n_blocks)
    pos = 0
    rate = 100.0
    for (name, *_), share, core, mf in zip(ops, shares, core_shares, mem_fracs):
        ln = max(int(round(share * n_blocks)), 1)
        r = rate  # uniform instruction rate; sensitivity split by core share
        sens[pos:pos + ln] = core * r / 1.7
        i0[pos:pos + ln] = (1 - core) * r
        mem[pos:pos + ln] = mf
        pos += ln
        if pos >= n_blocks:
            break
    if pos < n_blocks:  # pad with the last op's character
        sens[pos:] = sens[pos - 1]
        i0[pos:] = i0[pos - 1]
        mem[pos:] = mem[pos - 1]
    return _finalize(f"{cfg.name}:{shape.name}", i0, sens, mem)
