# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernels for the DVFS engine hot path.

Two generations ship:

* v1 — ``pc_table``: the fused PC-table predict / update pair (one
  ``pallas_call`` per table op, the rest of the epoch stays in XLA).
* v2 — ``epoch_fused``: ONE kernel for the whole fork--execute epoch
  (context gathers, predict, select, 11-way execute, counters, estimate,
  table update) so PC-table state never round-trips through HBM within
  an epoch. ``simulate._scan_sim`` auto-selects it behind the
  ``SimConfig.use_pallas`` flag.

``_resolve_interpret`` decides interpret vs compiled mode for every
kernel in this package; the ``REPRO_PALLAS_INTERPRET`` environment
variable overrides it without code edits (CI's kernels lane and
real-hardware A/B runs both use it).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

# REPRO_PALLAS_INTERPRET truth table (checked per call, so tests can
# monkeypatch os.environ): "1"/"true"/"yes" force interpret mode
# everywhere; "0"/"false"/"no" force the compiled path (which raises on
# CPU — JAX only lowers Pallas through Mosaic on TPU, so forcing
# compiled mode is a real-hardware knob); unset/"" defer to the
# explicit ``interpret=`` argument or, when that is None too, to the
# backend default (compiled on TPU, interpreted everywhere else).
_ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    env = os.environ.get(_ENV_INTERPRET, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env:
        raise ValueError(
            f"{_ENV_INTERPRET}={env!r}: expected one of {_TRUE + _FALSE}")
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
