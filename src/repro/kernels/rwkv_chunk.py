"""RWKV6 chunked linear-attention Pallas kernel.

The token-by-token recurrence (ref.py / models/rwkv.py) is VPU-bound on TPU:
every step does an hd x hd outer product with no MXU work. The chunked
formulation turns the bulk into matmuls (MXU-friendly):

For a chunk of C tokens with per-token decay w_t (diag), define suffix decay
products D_t = prod_{s>t} diag(w_s). Then for token t in the chunk:

  y_t = r_t @ (W_t S_0) + sum_{s<t} (r_t . k_s * prodw(s..t)) v_s + u-term
  S_C = D_all S_0 + sum_s D_(s..C) k_s^T v_s

where W_t = prod_{s<=t-1} diag(w_s) (prefix decay to chunk start). With
P_t = prefix products, intra-chunk weights form a (C,C) matrix
A[t,s] = (r_t * P_t / P_s) . k_s for s<t, plus the diagonal u bonus —
computed with two (C,hd)x(hd,C) matmuls, then y = A @ v and a (C,hd)x(hd,hd)
matmul against the carried state. The cross-chunk state recurrence stays
sequential over the grid's chunk axis (VMEM scratch carry).

Validated in interpret mode against the exact scan (``rwkv_chunk_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *, C: int):
    i_c = pl.program_id(1)

    @pl.when(i_c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)   # (C,hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)   # (C,hd) decays in (0,1)
    u = u_ref[0].astype(jnp.float32)   # (1,hd) -> (hd,)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)               # log prod_{s<=t} w_s
    P = jnp.exp(cum - logw)                      # prefix products EXCL t
    # intra-chunk attention: A[t,s] = sum_d r[t,d]k[s,d] * P[t,d]/ (P[s,d]*? )
    #   weight(s<t) = prod_{j=s+1..t-1} w_j = P_t / (P_s * w_s) — fold w_s
    #   into k: kd[s] = k[s] / (P[s] * w[s]) ... use exp-log for stability.
    rP = r * P
    kD = k * jnp.exp(-(cum))                     # k / prod_{s<=s} w
    A = jax.lax.dot_general(rP, kD, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C,C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(cols < rows, A, 0.0)
    # u bonus on the diagonal (current token)
    diag = jnp.sum(r * u[None, :] * k, axis=1)          # (C,)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    # contribution of carried state: y_t += (r_t * P_t) @ S0
    S0 = s_scr[...]
    y = y + jax.lax.dot_general(rP, S0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: S_C = diag(prod all w) S0 + sum_s diag(prod_{j>s} w) k_s^T v_s
    total = cum[-1]                               # (hd,)
    kT = k * jnp.exp(total - cum)[..., :]         # k_s * prod_{j>s} w_j
    s_scr[...] = jnp.exp(total)[:, None] * S0 + jax.lax.dot_general(
        kT, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def rwkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, *, chunk: int = 128,
                 interpret: bool = True) -> jax.Array:
    """Batched-heads RWKV6. r/k/v/w (BH, T, hd); u (BH, hd). Returns y."""
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_c = T // chunk
    kernel = functools.partial(_rwkv_kernel, C=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
