"""Pallas v2: ONE fused kernel for the whole fork--execute epoch.

v1 (``pc_table``) fused only the PC-table predict and update; everything
between them — the epoch context gathers, the objective-weighted frequency
select, the 11-way batched execute over the (mech, CU, WF) steady batch and
the per-row counter reduces — stayed in the XLA scan body and round-tripped
every intermediate through HBM. This kernel collapses the entire epoch:

    context gathers -> predict (PC table or reactive state) -> select
    -> 11-way execute (10 uniform fork rows + the selected mixed row)
    -> barrier/contention counters (selected row only) -> estimate
    -> fused table / reactive-state update

so the PC table is read and written inside one kernel invocation and no
(NF+1, CU, WF) intermediate ever leaves the kernel within an epoch.

Structure: the epoch body is a pure array function (``_epoch_math``) and
the Pallas kernel (``_epoch_kernel``) is a thin ref shim around it. The
execution engine is chosen by ``_resolve_interpret``:

* compiled (TPU): ``pl.pallas_call`` lowers ``_epoch_kernel`` through
  Mosaic — the actual fused-kernel target.
* interpret (CPU/GPU): ``_epoch_math`` is evaluated directly as XLA ops.
  ``pallas_call(interpret=True)`` would trace the kernel to the *same*
  ops but wraps every operand in the ref-simulation machinery, which
  costs a measured ~15-20% of the epoch on the CPU bench box for zero
  semantic difference; direct evaluation IS interpret mode minus that
  wrapper. ``via_pallas=True`` forces the real ``pallas_call`` interpret
  path (tests assert the two agree; CI's kernels lane runs both).

Layout and math notes (why the fused body is faster than the unfused
scan body even as plain XLA ops):

* the packed ``(2P+1, 3)`` cumulative table is consumed as three
  contiguous 1-D rows (``cum_t = cum3.T``): the window gathers become
  three dense 1-D gathers instead of one strided 12-byte gather;
* the body has two math modes (the static ``lean`` flag).
  ``lean=False`` orders every op exactly as the unfused reference
  (``_steady_parts`` / ``_row_counters`` / ``_select_freq``) — on the
  CPU backend it is empirically *bitwise* equal to the reference scan
  (a fusion-context accident, not a contract; the reference itself is
  not bitwise reproducible across XLA fusion contexts, see ROADMAP
  "numerics CAUTION"). ``lean=True`` — the engine default — applies
  three value-reassociating rewrites to the (NF, CU, WF) fork-row
  execute batch: the epoch scale and noise factor fold into one multiply
  (``(dci + dcs f) * (T (1+sigma eps)/nb)``), the intra-CU prefix sum
  becomes a tril matmul (GEMM instead of XLA's serialised cumsum), and
  the memory-scale blend reassociates to ``alloc - am (1-scale)``.
  Measured on the 2-core bench box these take the 64-CU epoch from
  ~1.23x to ~1.9x over the jnp scan body. The SELECTED row is excluded
  from the rewrites even in lean mode and always runs the exact
  reference op order: it advances the carry's program position, and one
  ulp of position decorrelates the sin-hash noise stream O(1) from the
  unfused body on the very first epoch. With the split, the lean
  perturbation reaches the carry only through the estimator/table
  state (one-ulp prediction shifts), so the argmin select flips on
  genuine near-ties only — per-epoch traces are typically bitwise vs
  the unfused body until such a flip, and the closed loop is chaotic
  from there. Aggregate work/energy deviations stay O(1e-4) relative
  over a 200-epoch run (the ``kernel_epoch``/``grid_kernel`` bench
  records report them). The fused path is *held* to aggregate
  tolerances and the default engine path stays jnp.
* the ``(blk, loop, wf, cu, seed)`` sin-hash noise rides IN as an operand
  (computed by the same ``_epoch_context`` code both paths share):
  ``frac(sin(x) * 43758)`` amplifies one ulp of a differently-fused sin
  into O(1) noise, so it is the one context piece the kernel must not
  recompute.

Traced-operand contract: ``epoch_us``, ``sigma``, ``cap_per_ghz``,
``membw``, ``table_ema``, the lowered objective vector, the transition
latency, the logical block count and the whole ``PowerAxes`` regime enter
as packed array operands — never as trace-time constants — so one
compiled kernel serves every grid point of a sweep (the no-retrace
contract of ``core.sweep``).

Table maps: the CU->table assignment ``tid`` is an ordinary int operand;
non-contiguous and uneven maps (e.g. ``tid=[0, 2, 1, 0]``) are supported
— out-of-range table ids clamp on lookup and drop on update, matching
``predictors.table_update``'s scatter semantics. (v1's
``pc_table_update`` still requires the contiguous grouped layout.)

The in-kernel table update has two formulations, switched on the
resolved execution mode: the interpret/direct path reuses
``predictors.table_update``'s packed scatter-add (bit-compatible with
the unfused reference); the compiled path lowers a scatter-free one-hot
masked matmul instead (Mosaic has no scatter). The compiled path is
untested until a TPU/GPU runner is attached — CI exercises interpret
mode only (see the kernels lane).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import estimators as EST
from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.kernels import _resolve_interpret

# number of packed f32 sweep scalars (see _pack_scal)
_N_SCAL = 9


class EpochOut(NamedTuple):
    """One epoch of engine state advance + telemetry, as returned by
    :func:`epoch_fused`. Reactive-family calls leave the table fields
    untouched (``None``); PC-family calls leave the reactive state
    untouched."""
    pos: jnp.ndarray                    # (CU,WF) advanced wave positions
    table: Optional[PRED.PCTable]       # updated PC table (pc family)
    wf_i0: Optional[jnp.ndarray]        # (CU,WF) per-WF estimates (pc)
    wf_sens: Optional[jnp.ndarray]
    react_i0: Optional[jnp.ndarray]     # (CU,) CU estimates (reactive)
    react_sens: Optional[jnp.ndarray]
    f_sel: jnp.ndarray                  # (CU,) executed GHz
    e_acc: jnp.ndarray                  # (CU,) accumulated energy
    t_acc: jnp.ndarray                  # (1,) accumulated time
    work: jnp.ndarray                   # (CU,) committed work
    energy: jnp.ndarray                 # (CU,) epoch energy
    err: jnp.ndarray                    # (CU,) |pred - actual| / actual
    fidx: jnp.ndarray                   # (CU,) int32 ladder index
    true_sens: jnp.ndarray              # (CU,) fork-exact CU sensitivity
    hit_rate: Optional[jnp.ndarray]     # (1,) table hit fraction (pc)


def _epoch_math(ins, *, NF, CU, WF, E, T_, ND, CPD, IPB, OFFB,
                family, fork_estimator, cu_model, mosaic, lean,
                react_models=(), pc_ids=(), id_ctr_pc=0):
    """The fused epoch body: pure arrays in, tuple of arrays out, in the
    operand/output order of :func:`epoch_fused`. Runs as the Pallas kernel
    body (via the ref shim below) or evaluated directly (the interpret
    engine).

    ``family='fork'`` is the traced-mechanism-id mode that serves the
    sweep layer's shared fork executable: the mechanism id rides in as a
    (1,) int32 operand, BOTH predictor paths and every estimator variant
    are computed, and ``jnp.where``/``jnp.select`` on the traced id pick
    the live one — mirroring the jnp traced scan body op-for-op.
    ``react_models`` names the counter estimators in traced-id order,
    ``pc_ids`` the table-maintaining ids, ``id_ctr_pc`` the counter-driven
    pc id (pcstall).

    ``lean=False`` orders every op exactly as the unfused reference
    (``simulate._epoch_context``/``_steady_parts``/``_row_counters``/
    ``_select_freq`` and the ``_scan_sim`` body). ``lean=True`` (the
    engine default) applies three value-reassociating rewrites to the
    (NF+1, CU, WF) execute batch — see the module docstring."""
    if family == "fork":
        (i0r, sr, cum_t, pb, pos, ti0, tse, tcnt, wfi, wfs, ri0, rse,
         fprev, eacc, tacc, F, tid, mech_op, eps, scal, pw_vec) = ins
        mech = mech_op[0]
    elif family == "pc":
        (i0r, sr, cum_t, pb, pos, ti0, tse, tcnt, wfi, wfs, fprev, eacc,
         tacc, F, tid, eps, scal, pw_vec) = ins
    else:
        (i0r, sr, cum_t, pb, pos, ri0, rse, fprev, eacc, tacc, F, eps,
         scal, pw_vec) = ins

    pw = PWR.PowerAxes(*[pw_vec[i]
                         for i in range(len(PWR.PowerAxes._fields))])
    T = scal[0]
    sigma = scal[1]
    cap = scal[2]
    membw = scal[3]
    ema = scal[4]
    w_pbar, use_rate, capf = scal[5], scal[6], scal[7]
    lat = scal[8]
    P = pb[0]                           # logical block count (traced)

    # ---- context: shared gathers (op order == _epoch_context) ------------
    blk = (pos.astype(jnp.int32) // IPB) % P
    i0_l = i0r[blk]
    s_l = sr[blk]
    c_i0 = cum_t[0]                     # (2P+1,) rows of cum3.T
    c_se = cum_t[1]
    c_mf = cum_t[2]
    lo_i0 = c_i0[blk]
    lo_se = c_se[blk]
    lo_mf = c_mf[blk]

    # ---- predict I(f) from carry state (== _pc_lookup / _predict_instr) --
    capr = cap * F[None, :] * T * WF
    hit_rate = None
    if family == "fork":
        # both predictor paths, selected on the traced mechanism id
        idx_lu = (blk // OFFB) % E      # == predictors.table_index
        hit = tcnt[tid[:, None], idx_lu] > 0
        hit_rate = hit.astype(jnp.float32).mean().reshape(1)
        i0_pc = jnp.where(hit, ti0[tid[:, None], idx_lu], wfi).sum(-1)
        s_pc = jnp.where(hit, tse[tid[:, None], idx_lu], wfs).sum(-1)
        I_pc = jnp.clip((i0_pc[:, None] + s_pc[:, None] * F[None, :]) * T,
                        0.0, capr)
        I_react = jnp.clip((ri0[:, None] + rse[:, None] * F[None, :]) * T,
                           0.0, capr)
        I_pred = jnp.where(mech < len(react_models) + 1, I_react, I_pc)
    else:
        if family == "pc":
            idx_lu = (blk // OFFB) % E  # == predictors.table_index
            t_i0 = ti0[tid[:, None], idx_lu]
            t_se = tse[tid[:, None], idx_lu]
            hit = tcnt[tid[:, None], idx_lu] > 0
            i0_cu = jnp.where(hit, t_i0, wfi).sum(-1)
            s_cu = jnp.where(hit, t_se, wfs).sum(-1)
            hit_rate = hit.astype(jnp.float32).mean().reshape(1)
        else:
            i0_cu = ri0
            s_cu = rse
        I_pred = (i0_cu[:, None] + s_cu[:, None] * F[None, :]) * T
        I_pred = jnp.clip(I_pred, 0.0, capr)

    # ---- per-domain frequency select (op order == _select_freq) ----------
    pbar = (eacc / jnp.maximum(tacc[0], 1e-3)).reshape(ND, CPD).sum(1)
    I_dom = I_pred.reshape(ND, CPD, NF)
    act = I_pred / (cap * F[None, :] * T * WF)
    p_cu = PWR.power(F[None, :], act, pw)
    P_dom = p_cu.reshape(ND, CPD, NF).sum(1)
    I_sum = jnp.maximum(I_dom.sum(1), 1e-3)
    denom = jnp.where(use_rate > 0.0, I_sum, 1.0)
    infeasible = I_sum < capf * I_sum[:, -1:]
    cost = (P_dom + w_pbar * pbar[:, None]) / denom + 1e9 * infeasible
    idx_dom = jnp.argmin(cost, axis=-1)
    fidx = jnp.repeat(idx_dom, CPD)
    f_sel = F[fidx]

    # ---- 11-way batched execute (op order == _steady_parts) --------------
    # In lean mode the value-reassociating rewrites apply to the FORK rows
    # only — they feed estimator telemetry, which perturbs predictions at
    # one ulp and flips a frequency decision only on a genuine near-tie.
    # The selected (executed) row is always computed with the exact
    # reference op order: it advances the carry's program position, and
    # one ulp there decorrelates the sin-hash noise stream O(1) from the
    # unfused body on the very first epoch (observed as the whole
    # aggregate-deviation budget of the grid A/B before this split).
    F_rows = jnp.broadcast_to(F[:, None], (NF, CU))
    f_all = F_rows if lean else jnp.concatenate([F_rows, f_sel[None]], 0)
    f_b = f_all[..., :, None]
    est_instr = (i0_l + s_l * f_b) * T
    nblk = jnp.clip((est_instr / IPB).astype(jnp.int32) + 1, 1, P)
    gi = blk + nblk
    nb = nblk.astype(jnp.float32)
    dci = c_i0[gi] - lo_i0              # window deltas (un-normalised)
    dcs = c_se[gi] - lo_se
    i0w = dci / nb
    sw = dcs / nb
    mfw = (c_mf[gi] - lo_mf) / nb
    if lean:
        # fold the epoch scale and noise factor into ONE multiply over
        # the big batch: (dci + dcs f) * (T (1 + sigma eps) / nb)
        demand = (dci + dcs * f_b) * ((T * (1.0 + sigma * eps)) / nb)
    else:
        demand = (i0w + sw * f_b) * T
        demand = demand * (1.0 + sigma * eps)
    C = cap * f_all * T
    if lean:
        # prefix sum as a tril matmul — XLA CPU lowers the dot through
        # the GEMM path, ~8x faster than its serialised cumsum here
        L = jnp.tril(jnp.ones((WF, WF), jnp.float32))
        before = jax.lax.dot_general(
            demand, L, (((2,), (1,)), ((), ()))) - demand
    else:
        before = jnp.cumsum(demand, axis=-1) - demand
    alloc = jnp.clip(C[..., :, None] - before, 0.0, demand)
    am = alloc * mfw
    traffic = am.sum(axis=(-2, -1))
    scale = jnp.minimum(1.0, membw * T / jnp.maximum(traffic, 1e-6))
    if lean:
        # alloc (1 - mfw (1-scale)) == alloc - am (1-scale), reusing am
        steady = alloc - am * (1.0 - scale[..., None, None])
    else:
        steady = alloc * (1.0 - mfw * (1.0 - scale[..., None, None]))
    c_f = steady[:NF]                   # (NF,CU,WF) fork rows
    I_f = c_f.sum(-1).T                 # (CU,NF)
    if lean:
        # exact selected row: same shared gathers, reference op order
        est_s = (i0_l + s_l * f_sel[:, None]) * T
        nblk_s = jnp.clip((est_s / IPB).astype(jnp.int32) + 1, 1, P)
        gi_s = blk + nblk_s
        nb_s = nblk_s.astype(jnp.float32)
        i0w_s = (c_i0[gi_s] - lo_i0) / nb_s
        sw_s = (c_se[gi_s] - lo_se) / nb_s
        mfw_s = (c_mf[gi_s] - lo_mf) / nb_s
        d_s = (i0w_s + sw_s * f_sel[:, None]) * T
        d_s = d_s * (1.0 + sigma * eps)
        C_s = cap * f_sel * T
        b_s = jnp.cumsum(d_s, axis=-1) - d_s
        a_s = jnp.clip(C_s[:, None] - b_s, 0.0, d_s)
        tr_s = (a_s * mfw_s).sum()
        sc_s = jnp.minimum(1.0, membw * T / jnp.maximum(tr_s, 1e-6))
        st_sel = a_s * (1.0 - mfw_s * (1.0 - sc_s))
    else:
        i0w_s, sw_s, mfw_s = i0w[NF], sw[NF], mfw[NF]
        d_s, a_s = demand[NF], alloc[NF]
        st_sel = steady[NF]             # the executed mixed row

    # ---- selected-row counters (op order == _row_counters) ---------------
    q = a_s / jnp.maximum(d_s, 1e-6)
    plen = (P * IPB).astype(jnp.float32)
    tentative = pos + st_sel
    group_min = tentative.min(axis=-1)
    boundary = (jnp.floor(group_min / plen) + 1.0) * plen
    committed = jnp.minimum(st_sel,
                            jnp.maximum(boundary[:, None] - pos, 0.0))
    core_frac = sw_s * f_sel[:, None] \
        / jnp.maximum(i0w_s + sw_s * f_sel[:, None], 1e-6)

    # ---- transition overhead, telemetry, energy (== _scan_sim body) ------
    trans = (f_sel != fprev)
    committed = committed * (1.0 - lat / T * trans[:, None])
    I_actual = st_sel.sum(-1)
    work = committed.sum(-1)
    I_at_sel = jnp.take_along_axis(I_pred, fidx[:, None], 1)[:, 0]
    err = jnp.abs(I_at_sel - I_actual) / jnp.maximum(I_actual, 1e-3)
    act_w = work / (cap * f_sel * T * WF)
    energy = PWR.power(f_sel, act_w, pw) * T \
        + PWR.transition_energy(fprev, f_sel, pw) * trans

    # ---- estimate + state update -----------------------------------------
    ctrs = {"committed": st_sel, "steady": st_sel, "core_frac": core_frac,
            "issue_q": q, "mem_frac": mfw_s}
    tsens = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)

    def _tbl_update(tbl0, i0_wf, s_wf):
        if mosaic:
            # scatter-free update: one-hot slot mask contracted per CU,
            # then a (T, CU) table-assignment matmul — arbitrary tid maps,
            # out-of-range ids contribute nowhere (scatter-drop semantics)
            slots = jax.lax.broadcasted_iota(jnp.int32, (CU, WF, E), 2)
            oh = (idx_lu[:, :, None] == slots).astype(jnp.float32)
            vals = jnp.stack([i0_wf, s_wf, jnp.ones_like(i0_wf)], axis=-1)
            scat = jax.lax.dot_general(                       # (CU,E,3)
                oh, vals, (((1,), (1,)), ((0,), (0,))))
            t1h = (tid[None, :] ==
                   jax.lax.broadcasted_iota(jnp.int32, (T_, CU), 0)
                   ).astype(jnp.float32)
            agg = jax.lax.dot_general(                        # (T_,E*3)
                t1h, scat.reshape(CU, E * 3),
                (((1,), (0,)), ((), ()))).reshape(T_, E, 3)
            isum, ssum, cnt = agg[..., 0], agg[..., 1], agg[..., 2]
            snew = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), 0.0)
            inew = jnp.where(cnt > 0, isum / jnp.maximum(cnt, 1), 0.0)
            fresh = (tbl0.count == 0) & (cnt > 0)
            blend = jnp.where(fresh, 1.0, jnp.where(cnt > 0, ema, 0.0))
            return PRED.PCTable(tbl0.i0 * (1 - blend) + inew * blend,
                                tbl0.sens * (1 - blend) + snew * blend,
                                tbl0.count + cnt)
        # interpret/direct mode is XLA anyway: reuse the reference
        # packed scatter-add verbatim (bit-compatible collision sums)
        return PRED.table_update(tbl0, tid, idx_lu, i0_wf, s_wf, ema)

    if family == "fork":
        # every estimator variant, selected on the traced id — the op
        # order mirrors the jnp traced scan body (ctrs already carries
        # the estimator view: committed == steady)
        n_react = len(react_models) + 1
        cu_ests = [EST.cu_estimate(ctrs, f_sel, m) for m in react_models]
        sens_ar = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
        i0_ar = I_f[:, 0] / T - sens_ar * F[0]
        sel = [mech == k for k in range(n_react)]
        r_i0 = jnp.select(sel, [e[0] / T for e in cu_ests] + [i0_ar], ri0)
        r_se = jnp.select(sel, [e[1] / T for e in cu_ests] + [sens_ar], rse)
        i0_est, s_est = EST.wf_stall_estimate(ctrs, f_sel)
        s_tr = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
        i0_tr = c_f[0] - s_tr * F[0]
        i0_wf = jnp.where(mech == id_ctr_pc, i0_est, i0_tr) / T
        s_wf = jnp.where(mech == id_ctr_pc, s_est, s_tr) / T
        tbl0 = PRED.PCTable(ti0, tse, tcnt)
        tbl_u = _tbl_update(tbl0, i0_wf, s_wf)
        pc_now = functools.reduce(lambda a, b: a | b,
                                  [mech == i for i in pc_ids])
        tbl = jax.tree.map(lambda a, b: jnp.where(pc_now, a, b), tbl_u,
                           tbl0)
        state = (tbl.i0, tbl.sens, tbl.count,
                 jnp.where(pc_now, i0_wf, wfi),
                 jnp.where(pc_now, s_wf, wfs), r_i0, r_se)
    elif family == "pc":
        if fork_estimator:              # accpc: exact per-WF linear model
            s_wf = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
            i0_wf = c_f[0] - s_wf * F[0]
        else:                           # pcstall: counter-driven
            i0_wf, s_wf = EST.wf_stall_estimate(ctrs, f_sel)
        i0_wf, s_wf = i0_wf / T, s_wf / T
        tbl = _tbl_update(PRED.PCTable(ti0, tse, tcnt), i0_wf, s_wf)
        state = (tbl.i0, tbl.sens, tbl.count, i0_wf, s_wf)
    else:
        if fork_estimator:              # accreac: exact linear from forks
            s_est = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
            i0_est = I_f[:, 0] / T - s_est * F[0]
        else:                           # counter model (stall/lead/...)
            i0_c, s_c = EST.cu_estimate(ctrs, f_sel, cu_model)
            i0_est, s_est = i0_c / T, s_c / T
        state = (i0_est, s_est)

    outs = (pos + committed,) + state + (
        f_sel, eacc + energy, (tacc + T).reshape(1), work, energy, err,
        fidx.astype(jnp.int32), tsens)
    if family in ("pc", "fork"):
        outs = outs + (hit_rate,)
    return outs


def _epoch_kernel(*refs, n_in, **statics):
    """Ref shim: read operands, run :func:`_epoch_math`, write outputs."""
    ins = tuple(r[...] for r in refs[:n_in])
    for o_ref, o in zip(refs[n_in:], _epoch_math(ins, **statics)):
        o_ref[...] = o


# ---------------------------------------------------------------------------
# Blocked (CU,)-grid fork variant
# ---------------------------------------------------------------------------
# At 64-256 CUs the monolithic kernel materializes the whole (NF+1, CU, WF)
# execute batch at once; the blocked variant tiles the CU axis over a 1-D
# Pallas grid instead. The epoch has exactly ONE cross-CU dependency chain:
#
#   select  -> depends only on carry state + the power budget (NOT on this
#              epoch's execute), and each frequency domain is whole inside a
#              block (asserted block_cu % cus_per_domain == 0) — so f_sel is
#              block-local and EXACT;
#   traffic -> the memory-scale reduction sums am over ALL CUs, and the
#              table update aggregates per-table sums over ALL CUs.
#
# So the epoch splits into two grid passes plus a tiny jnp epilogue:
# kernel A computes predict/select per block and accumulates the global
# traffic (+ table-hit count); kernel B re-derives the block's execute
# batch (duplicated compute — the win is peak memory), consumes the global
# traffic, and advances all per-CU state, accumulating the raw per-table
# (T, E, 3) sums; the epilogue applies the EMA blend, the pc-mode gate and
# the time accumulator. Cross-block accumulation uses the standard Pallas
# reduction idiom: a constant-index-map output zero-initialised at
# program_id 0 and "+="-ed every step (grid steps are sequential on TPU
# and in interpret mode). The two reductions re-associate float sums
# across blocks, so blocked results are held to the same aggregate
# tolerances as lean math (f_sel/fidx stay exactly equal to the
# unblocked kernel — the select math is untiled-identical); the blocked
# body implements the lean math mode only and always uses the one-hot
# matmul table update (the only blockable formulation).


def _fork_blk_a(i0r_r, sr_r, cum_r, pb_r, ti0_r, tse_r, tcnt_r, F_r,
                mech_r, scal_r, pw_r, tacc_r, pos_r, wfi_r, wfs_r, ri0_r,
                rse_r, eacc_r, tid_r, eps_r,
                fsel_o, fidx_o, iat_o, traf_o, hit_o, *,
                NF, BCU, WF, E, CPD, IPB, OFFB, react_models):
    """Blocked pass A: predict + select + traffic partials for one block."""
    f32 = jnp.float32
    i0r, sr, cum_t = i0r_r[...], sr_r[...], cum_r[...]
    P = pb_r[...][0]
    ti0, tse, tcnt = ti0_r[...], tse_r[...], tcnt_r[...]
    F, mech = F_r[...], mech_r[...][0]
    scal, pw_vec, tacc = scal_r[...], pw_r[...], tacc_r[...]
    pos, wfi, wfs = pos_r[...], wfi_r[...], wfs_r[...]
    ri0, rse, eacc = ri0_r[...], rse_r[...], eacc_r[...]
    tid, eps = tid_r[...], eps_r[...]
    pw = PWR.PowerAxes(*[pw_vec[i]
                         for i in range(len(PWR.PowerAxes._fields))])
    T, sigma, cap = scal[0], scal[1], scal[2]
    w_pbar, use_rate, capf = scal[5], scal[6], scal[7]

    blk = (pos.astype(jnp.int32) // IPB) % P
    i0_l, s_l = i0r[blk], sr[blk]
    c_i0, c_se, c_mf = cum_t[0], cum_t[1], cum_t[2]
    lo_i0, lo_se, lo_mf = c_i0[blk], c_se[blk], c_mf[blk]

    capr = cap * F[None, :] * T * WF
    idx_lu = (blk // OFFB) % E
    hit = tcnt[tid[:, None], idx_lu] > 0
    i0_pc = jnp.where(hit, ti0[tid[:, None], idx_lu], wfi).sum(-1)
    s_pc = jnp.where(hit, tse[tid[:, None], idx_lu], wfs).sum(-1)
    I_pc = jnp.clip((i0_pc[:, None] + s_pc[:, None] * F[None, :]) * T,
                    0.0, capr)
    I_react = jnp.clip((ri0[:, None] + rse[:, None] * F[None, :]) * T,
                       0.0, capr)
    I_pred = jnp.where(mech < len(react_models) + 1, I_react, I_pc)

    NDb = BCU // CPD                    # whole domains per block
    pbar = (eacc / jnp.maximum(tacc[0], 1e-3)).reshape(NDb, CPD).sum(1)
    I_dom = I_pred.reshape(NDb, CPD, NF)
    act = I_pred / (cap * F[None, :] * T * WF)
    p_cu = PWR.power(F[None, :], act, pw)
    P_dom = p_cu.reshape(NDb, CPD, NF).sum(1)
    I_sum = jnp.maximum(I_dom.sum(1), 1e-3)
    denom = jnp.where(use_rate > 0.0, I_sum, 1.0)
    infeasible = I_sum < capf * I_sum[:, -1:]
    cost = (P_dom + w_pbar * pbar[:, None]) / denom + 1e9 * infeasible
    idx_dom = jnp.argmin(cost, axis=-1)
    fidx = jnp.repeat(idx_dom, CPD)
    f_sel = F[fidx]

    # the block's slice of the 11-way execute, down to the am partials
    F_rows = jnp.broadcast_to(F[:, None], (NF, BCU))
    f_all = jnp.concatenate([F_rows, f_sel[None]], axis=0)
    f_b = f_all[..., :, None]
    est_instr = (i0_l + s_l * f_b) * T
    nblk = jnp.clip((est_instr / IPB).astype(jnp.int32) + 1, 1, P)
    gi = blk + nblk
    nb = nblk.astype(f32)
    dci = c_i0[gi] - lo_i0
    dcs = c_se[gi] - lo_se
    mfw = (c_mf[gi] - lo_mf) / nb
    demand = (dci + dcs * f_b) * ((T * (1.0 + sigma * eps)) / nb)
    C = cap * f_all * T
    L = jnp.tril(jnp.ones((WF, WF), f32))
    before = jax.lax.dot_general(
        demand, L, (((2,), (1,)), ((), ()))) - demand
    alloc = jnp.clip(C[..., :, None] - before, 0.0, demand)
    am = alloc * mfw

    fsel_o[...] = f_sel
    fidx_o[...] = fidx.astype(jnp.int32)
    iat_o[...] = jnp.take_along_axis(I_pred, fidx[:, None], 1)[:, 0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        traf_o[...] = jnp.zeros(traf_o.shape, traf_o.dtype)
        hit_o[...] = jnp.zeros(hit_o.shape, hit_o.dtype)
    traf_o[...] += am.sum(axis=(-2, -1))
    hit_o[...] += hit.astype(f32).sum().reshape(1)


def _fork_blk_b(i0r_r, sr_r, cum_r, pb_r, F_r, mech_r, scal_r, pw_r,
                traf_r, pos_r, wfi_r, wfs_r, ri0_r, rse_r, fprev_r, eacc_r,
                tid_r, eps_r, fsel_r, fidx_r, iat_r,
                pos_o, wfi_o, wfs_o, ri0_o, rse_o, eacc_o, work_o, en_o,
                err_o, tsens_o, agg_o, *,
                NF, BCU, WF, E, T_, CPD, IPB, OFFB, react_models, pc_ids,
                id_ctr_pc):
    """Blocked pass B: execute + counters + state advance for one block,
    consuming the global traffic from pass A (the execute batch is
    re-derived per block — duplicated compute, but no (NF+1, CU, WF)
    array ever exists)."""
    f32 = jnp.float32
    i0r, sr, cum_t = i0r_r[...], sr_r[...], cum_r[...]
    P = pb_r[...][0]
    F, mech = F_r[...], mech_r[...][0]
    scal, pw_vec = scal_r[...], pw_r[...]
    traffic = traf_r[...]               # GLOBAL (NF+1,) sums
    pos, wfi, wfs = pos_r[...], wfi_r[...], wfs_r[...]
    ri0, rse = ri0_r[...], rse_r[...]
    fprev, eacc = fprev_r[...], eacc_r[...]
    tid, eps = tid_r[...], eps_r[...]
    f_sel, fidx = fsel_r[...], fidx_r[...]
    I_at_sel = iat_r[...]
    pw = PWR.PowerAxes(*[pw_vec[i]
                         for i in range(len(PWR.PowerAxes._fields))])
    T, sigma, cap, membw = scal[0], scal[1], scal[2], scal[3]
    lat = scal[8]

    blk = (pos.astype(jnp.int32) // IPB) % P
    i0_l, s_l = i0r[blk], sr[blk]
    c_i0, c_se, c_mf = cum_t[0], cum_t[1], cum_t[2]
    lo_i0, lo_se, lo_mf = c_i0[blk], c_se[blk], c_mf[blk]

    F_rows = jnp.broadcast_to(F[:, None], (NF, BCU))
    f_all = jnp.concatenate([F_rows, f_sel[None]], axis=0)
    f_b = f_all[..., :, None]
    est_instr = (i0_l + s_l * f_b) * T
    nblk = jnp.clip((est_instr / IPB).astype(jnp.int32) + 1, 1, P)
    gi = blk + nblk
    nb = nblk.astype(f32)
    dci = c_i0[gi] - lo_i0
    dcs = c_se[gi] - lo_se
    i0w = dci / nb
    sw = dcs / nb
    mfw = (c_mf[gi] - lo_mf) / nb
    demand = (dci + dcs * f_b) * ((T * (1.0 + sigma * eps)) / nb)
    C = cap * f_all * T
    L = jnp.tril(jnp.ones((WF, WF), f32))
    before = jax.lax.dot_general(
        demand, L, (((2,), (1,)), ((), ()))) - demand
    alloc = jnp.clip(C[..., :, None] - before, 0.0, demand)
    am = alloc * mfw
    scale = jnp.minimum(1.0, membw * T / jnp.maximum(traffic, 1e-6))
    steady = alloc - am * (1.0 - scale[..., None, None])
    c_f = steady[:NF]
    I_f = c_f.sum(-1).T
    st_sel = steady[NF]

    q = alloc[NF] / jnp.maximum(demand[NF], 1e-6)
    plen = (P * IPB).astype(f32)
    tentative = pos + st_sel
    group_min = tentative.min(axis=-1)
    boundary = (jnp.floor(group_min / plen) + 1.0) * plen
    committed = jnp.minimum(st_sel,
                            jnp.maximum(boundary[:, None] - pos, 0.0))
    core_frac = sw[NF] * f_sel[:, None] \
        / jnp.maximum(i0w[NF] + sw[NF] * f_sel[:, None], 1e-6)

    trans = (f_sel != fprev)
    committed = committed * (1.0 - lat / T * trans[:, None])
    I_actual = st_sel.sum(-1)
    work = committed.sum(-1)
    err = jnp.abs(I_at_sel - I_actual) / jnp.maximum(I_actual, 1e-3)
    act_w = work / (cap * f_sel * T * WF)
    energy = PWR.power(f_sel, act_w, pw) * T \
        + PWR.transition_energy(fprev, f_sel, pw) * trans

    ctrs = {"committed": st_sel, "steady": st_sel, "core_frac": core_frac,
            "issue_q": q, "mem_frac": mfw[NF]}
    tsens = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
    n_react = len(react_models) + 1
    cu_ests = [EST.cu_estimate(ctrs, f_sel, m) for m in react_models]
    sens_ar = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
    i0_ar = I_f[:, 0] / T - sens_ar * F[0]
    sel = [mech == k for k in range(n_react)]
    r_i0 = jnp.select(sel, [e[0] / T for e in cu_ests] + [i0_ar], ri0)
    r_se = jnp.select(sel, [e[1] / T for e in cu_ests] + [sens_ar], rse)
    i0_est, s_est = EST.wf_stall_estimate(ctrs, f_sel)
    s_tr = (c_f[-1] - c_f[0]) / (F[-1] - F[0])
    i0_tr = c_f[0] - s_tr * F[0]
    i0_wf = jnp.where(mech == id_ctr_pc, i0_est, i0_tr) / T
    s_wf = jnp.where(mech == id_ctr_pc, s_est, s_tr) / T
    pc_now = functools.reduce(lambda a, b: a | b,
                              [mech == i for i in pc_ids])

    # raw per-table sums for this block (one-hot matmul; tid carries
    # GLOBAL table ids so rows land in the right global slot, oob drops)
    idx_lu = (blk // OFFB) % E
    slots = jax.lax.broadcasted_iota(jnp.int32, (BCU, WF, E), 2)
    oh = (idx_lu[:, :, None] == slots).astype(f32)
    vals = jnp.stack([i0_wf, s_wf, jnp.ones_like(i0_wf)], axis=-1)
    scat = jax.lax.dot_general(oh, vals, (((1,), (1,)), ((0,), (0,))))
    t1h = (tid[None, :] ==
           jax.lax.broadcasted_iota(jnp.int32, (T_, BCU), 0)).astype(f32)
    agg = jax.lax.dot_general(t1h, scat.reshape(BCU, E * 3),
                              (((1,), (0,)), ((), ()))).reshape(T_, E, 3)

    pos_o[...] = pos + committed
    wfi_o[...] = jnp.where(pc_now, i0_wf, wfi)
    wfs_o[...] = jnp.where(pc_now, s_wf, wfs)
    ri0_o[...] = r_i0
    rse_o[...] = r_se
    eacc_o[...] = eacc + energy
    work_o[...] = work
    en_o[...] = energy
    err_o[...] = err
    tsens_o[...] = tsens

    @pl.when(pl.program_id(0) == 0)
    def _init():
        agg_o[...] = jnp.zeros(agg_o.shape, agg_o.dtype)
    agg_o[...] += agg


def _fork_blocked(operands, statics, *, block_cu, interpret):
    """Run the fork-family epoch as two (CU // block_cu,)-grid
    ``pallas_call``s plus a jnp epilogue (see the blocked-variant comment
    above). Takes the monolithic fork operand tuple and statics dict and
    returns the same 17-output tuple as ``_epoch_math(family='fork')``."""
    (i0r, sr, cum_t, pb, pos, ti0, tse, tcnt, wfi, wfs, ri0, rse, fprev,
     eacc, tacc, F, tid, mech, eps, scal, pw_vec) = operands
    NF, CU, WF = statics["NF"], statics["CU"], statics["WF"]
    E, T_ = statics["E"], statics["T_"]
    f32 = jnp.float32
    grid = (CU // block_cu,)

    def full(a):
        nd = a.ndim
        return pl.BlockSpec(a.shape, lambda i, _n=nd: (0,) * _n)

    def blk(a):
        if a.ndim == 2:
            return pl.BlockSpec((block_cu, a.shape[1]), lambda i: (i, 0))
        return pl.BlockSpec((block_cu,), lambda i: (i,))

    kst = dict(NF=NF, BCU=block_cu, WF=WF, E=E, CPD=statics["CPD"],
               IPB=statics["IPB"], OFFB=statics["OFFB"],
               react_models=statics["react_models"])
    a_full = (i0r, sr, cum_t, pb, ti0, tse, tcnt, F, mech, scal, pw_vec,
              tacc)
    a_blk = (pos, wfi, wfs, ri0, rse, eacc, tid, eps)
    f_sel, fidx, iat, traffic, hit_sum = pl.pallas_call(
        functools.partial(_fork_blk_a, **kst),
        grid=grid,
        in_specs=[full(a) for a in a_full] + [blk(a) for a in a_blk],
        out_specs=[
            pl.BlockSpec((block_cu,), lambda i: (i,)),
            pl.BlockSpec((block_cu,), lambda i: (i,)),
            pl.BlockSpec((block_cu,), lambda i: (i,)),
            pl.BlockSpec((NF + 1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((CU,), f32),
                   jax.ShapeDtypeStruct((CU,), jnp.int32),
                   jax.ShapeDtypeStruct((CU,), f32),
                   jax.ShapeDtypeStruct((NF + 1,), f32),
                   jax.ShapeDtypeStruct((1,), f32)],
        interpret=interpret,
    )(*(a_full + a_blk))

    kst_b = dict(kst, T_=T_, pc_ids=statics["pc_ids"],
                 id_ctr_pc=statics["id_ctr_pc"])
    b_full = (i0r, sr, cum_t, pb, F, mech, scal, pw_vec, traffic)
    b_blk = (pos, wfi, wfs, ri0, rse, fprev, eacc, tid, eps, f_sel, fidx,
             iat)
    cu1 = [(jax.ShapeDtypeStruct((CU,), f32),
            pl.BlockSpec((block_cu,), lambda i: (i,)))] * 6
    cu2 = [(jax.ShapeDtypeStruct((CU, WF), f32),
            pl.BlockSpec((block_cu, WF), lambda i: (i, 0)))] * 3
    b_out = cu2 + cu1[:2] + cu1[:1] * 5 + [
        (jax.ShapeDtypeStruct((T_, E, 3), f32),
         pl.BlockSpec((T_, E, 3), lambda i: (0, 0, 0)))]
    outs = pl.pallas_call(
        functools.partial(_fork_blk_b, **kst_b),
        grid=grid,
        in_specs=[full(a) for a in b_full] + [blk(a) for a in b_blk],
        out_specs=[s for _, s in b_out],
        out_shape=[s for s, _ in b_out],
        interpret=interpret,
    )(*(b_full + b_blk))
    (pos_n, wfi_n, wfs_n, r_i0, r_se, eacc_n, work, energy, err, tsens,
     agg) = outs

    # epilogue: EMA blend of the globally-aggregated table sums + the
    # pc-mode gate + the scalar accumulators (plain jnp — O(T*E))
    T, ema = scal[0], scal[4]
    isum, ssum, cnt = agg[..., 0], agg[..., 1], agg[..., 2]
    snew = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), 0.0)
    inew = jnp.where(cnt > 0, isum / jnp.maximum(cnt, 1), 0.0)
    fresh = (tcnt == 0) & (cnt > 0)
    blend = jnp.where(fresh, 1.0, jnp.where(cnt > 0, ema, 0.0))
    m = mech[0]
    pc_now = functools.reduce(lambda a, b: a | b,
                              [m == i for i in statics["pc_ids"]])
    nti0 = jnp.where(pc_now, ti0 * (1 - blend) + inew * blend, ti0)
    ntse = jnp.where(pc_now, tse * (1 - blend) + snew * blend, tse)
    ntcnt = jnp.where(pc_now, tcnt + cnt, tcnt)
    hit_rate = (hit_sum / (CU * WF)).reshape(1)
    return (pos_n, nti0, ntse, ntcnt, wfi_n, wfs_n, r_i0, r_se, f_sel,
            eacc_n, (tacc + T).reshape(1), work, energy, err, fidx, tsens,
            hit_rate)


def _pack_scal(epoch_us, sigma, cap_per_ghz, membw, table_ema, obj, lat_us
               ) -> jnp.ndarray:
    """Pack the traced sweep scalars into one (9,) f32 operand: [epoch_us,
    sigma, cap_per_ghz, membw, table_ema, obj0, obj1, obj2, lat_us]."""
    obj = jnp.asarray(obj, jnp.float32)
    return jnp.concatenate([
        jnp.stack([jnp.asarray(x, jnp.float32) for x in
                   (epoch_us, sigma, cap_per_ghz, membw, table_ema)]),
        obj.reshape(3),
        jnp.asarray(lat_us, jnp.float32).reshape(1)])


def epoch_fused(i0_rate: jax.Array, sens_rate: jax.Array, cum_t: jax.Array,
                pos: jax.Array, freqs: jax.Array, eps: jax.Array,
                f_prev: jax.Array, e_acc: jax.Array, t_acc: jax.Array, *,
                p_blocks, epoch_us, sigma, cap_per_ghz, membw, obj, lat_us,
                power, cus_per_domain: int = 1,
                # pc family state
                table: Optional[PRED.PCTable] = None,
                tid: Optional[jax.Array] = None,
                wf_i0: Optional[jax.Array] = None,
                wf_sens: Optional[jax.Array] = None,
                table_ema=0.5, offset_blocks: int = 4,
                # reactive family state
                react_i0: Optional[jax.Array] = None,
                react_sens: Optional[jax.Array] = None,
                # fork (traced-mechanism-id) family
                mech: Optional[jax.Array] = None,
                react_models: Tuple[str, ...] = (),
                pc_ids: Tuple[int, ...] = (),
                id_ctr_pc: int = 0,
                block_cu: Optional[int] = None,
                # mechanism shape
                family: str = "pc", fork_estimator: bool = False,
                cu_model: Optional[str] = None,
                instr_per_block: int = 4, lean: bool = True,
                interpret: Optional[bool] = None,
                via_pallas: Optional[bool] = None) -> EpochOut:
    """Run one fused fork--execute epoch.

    ``i0_rate``/``sens_rate`` are the (padded) per-block program rates;
    ``cum_t`` is the cumulative table TRANSPOSED to ``(3, 2P+1)`` (three
    contiguous gather rows — build it once per program with
    ``jnp.transpose(prog.cum3)``). ``eps`` is the (CU,WF) epoch noise from
    ``simulate._epoch_context`` (see module docstring for why it rides in).
    Every keyword in the first group may be a traced scalar/vector (sweep
    axes); ``power`` is a ``PowerAxes``/``PowerConfig``; the second/third
    groups select the mechanism family exactly like the unfused body:
    ``family='pc'`` needs ``table/tid/wf_i0/wf_sens``, ``family='reactive'``
    needs ``react_i0/react_sens`` (+ ``cu_model`` unless
    ``fork_estimator``). ``family='fork'`` is the traced-mechanism-id mode
    serving the sweep layer's shared fork executable: it needs BOTH state
    groups plus ``mech`` (a traced scalar id), ``react_models`` (counter
    estimator names in traced-id order), ``pc_ids`` and ``id_ctr_pc``;
    ``block_cu`` optionally tiles the CU axis over a (CU // block_cu,)
    Pallas grid (two passes + epilogue — see the blocked-variant comment;
    ignored on the direct-eval interpret engine, where there is no
    (VMEM) reason to tile and the monolithic body is the reference).

    ``lean`` selects the math mode: True (default) runs the reassociated
    fast body, False pins the exact reference op order (bitwise-in-engine
    on CPU; use for debugging a divergence) — see the module docstring.

    Engine: compiled mode lowers the kernel through ``pl.pallas_call``;
    interpret mode evaluates the kernel body directly as XLA ops unless
    ``via_pallas=True`` forces the (slower, semantically identical)
    ``pallas_call(interpret=True)`` ref simulation — see module docstring.
    """
    CU, WF = pos.shape
    NF = freqs.shape[0]
    assert family in ("pc", "reactive", "fork"), family
    assert CU % cus_per_domain == 0, (CU, cus_per_domain)
    ND = CU // cus_per_domain
    interp = _resolve_interpret(interpret)

    scal = _pack_scal(epoch_us, sigma, cap_per_ghz, membw, table_ema, obj,
                      lat_us)
    pw_vec = jnp.stack([jnp.asarray(getattr(power, f), jnp.float32)
                        for f in PWR.PowerAxes._fields])
    pb = jnp.asarray(p_blocks, jnp.int32).reshape(1)
    f32 = jnp.float32

    if family == "fork":
        T_, E = table.i0.shape
        statics = dict(NF=NF, CU=CU, WF=WF, E=E, T_=T_, ND=ND,
                       CPD=cus_per_domain, IPB=instr_per_block,
                       OFFB=offset_blocks, family=family,
                       fork_estimator=False, cu_model=None,
                       react_models=tuple(react_models),
                       pc_ids=tuple(pc_ids), id_ctr_pc=id_ctr_pc,
                       mosaic=not interp, lean=lean)
        operands = (i0_rate.astype(f32), sens_rate.astype(f32),
                    cum_t.astype(f32), pb, pos.astype(f32),
                    table.i0.astype(f32), table.sens.astype(f32),
                    table.count.astype(f32), wf_i0.astype(f32),
                    wf_sens.astype(f32), react_i0.astype(f32),
                    react_sens.astype(f32), f_prev.astype(f32),
                    e_acc.astype(f32), jnp.asarray(t_acc, f32).reshape(1),
                    freqs.astype(f32), tid.astype(jnp.int32),
                    jnp.asarray(mech, jnp.int32).reshape(1),
                    eps.astype(f32), scal, pw_vec)
        out_shape = [jax.ShapeDtypeStruct(s, d) for s, d in [
            ((CU, WF), f32),                               # pos
            ((T_, E), f32), ((T_, E), f32), ((T_, E), f32),  # table
            ((CU, WF), f32), ((CU, WF), f32),              # wf_i0 / wf_sens
            ((CU,), f32), ((CU,), f32),                    # react_i0 / sens
            ((CU,), f32), ((CU,), f32), ((1,), f32),       # f_sel/e_acc/t_acc
            ((CU,), f32), ((CU,), f32), ((CU,), f32),      # work/energy/err
            ((CU,), jnp.int32), ((CU,), f32), ((1,), f32)]]  # fidx/sens/hit
    elif family == "pc":
        T_, E = table.i0.shape
        statics = dict(NF=NF, CU=CU, WF=WF, E=E, T_=T_, ND=ND,
                       CPD=cus_per_domain, IPB=instr_per_block,
                       OFFB=offset_blocks, family=family,
                       fork_estimator=fork_estimator, cu_model=None,
                       mosaic=not interp, lean=lean)
        operands = (i0_rate.astype(f32), sens_rate.astype(f32),
                    cum_t.astype(f32), pb, pos.astype(f32),
                    table.i0.astype(f32), table.sens.astype(f32),
                    table.count.astype(f32), wf_i0.astype(f32),
                    wf_sens.astype(f32), f_prev.astype(f32),
                    e_acc.astype(f32), jnp.asarray(t_acc, f32).reshape(1),
                    freqs.astype(f32), tid.astype(jnp.int32),
                    eps.astype(f32), scal, pw_vec)
        out_shape = [jax.ShapeDtypeStruct(s, d) for s, d in [
            ((CU, WF), f32),                               # pos
            ((T_, E), f32), ((T_, E), f32), ((T_, E), f32),  # table
            ((CU, WF), f32), ((CU, WF), f32),              # wf_i0 / wf_sens
            ((CU,), f32), ((CU,), f32), ((1,), f32),       # f_sel/e_acc/t_acc
            ((CU,), f32), ((CU,), f32), ((CU,), f32),      # work/energy/err
            ((CU,), jnp.int32), ((CU,), f32), ((1,), f32)]]  # fidx/sens/hit
    else:
        statics = dict(NF=NF, CU=CU, WF=WF, E=0, T_=0, ND=ND,
                       CPD=cus_per_domain, IPB=instr_per_block,
                       OFFB=offset_blocks, family=family,
                       fork_estimator=fork_estimator, cu_model=cu_model,
                       mosaic=not interp, lean=lean)
        operands = (i0_rate.astype(f32), sens_rate.astype(f32),
                    cum_t.astype(f32), pb, pos.astype(f32),
                    react_i0.astype(f32), react_sens.astype(f32),
                    f_prev.astype(f32), e_acc.astype(f32),
                    jnp.asarray(t_acc, f32).reshape(1),
                    freqs.astype(f32), eps.astype(f32), scal, pw_vec)
        out_shape = [jax.ShapeDtypeStruct(s, d) for s, d in [
            ((CU, WF), f32),                               # pos
            ((CU,), f32), ((CU,), f32),                    # react_i0 / sens
            ((CU,), f32), ((CU,), f32), ((1,), f32),       # f_sel/e_acc/t_acc
            ((CU,), f32), ((CU,), f32), ((CU,), f32),      # work/energy/err
            ((CU,), jnp.int32), ((CU,), f32)]]             # fidx/true_sens

    if family == "fork" and block_cu is not None \
            and (not interp or via_pallas):
        # the blocked (CU,)-grid variant — only meaningful through a real
        # pallas_call (direct eval has no VMEM to tile for; the monolithic
        # body stays the interpret-engine reference)
        assert lean, "the blocked fork kernels implement lean math only"
        assert CU % block_cu == 0, (CU, block_cu)
        assert block_cu % cus_per_domain == 0, (block_cu, cus_per_domain)
        outs = _fork_blocked(operands, statics, block_cu=block_cu,
                             interpret=interp)
    elif interp and not via_pallas:
        # the interpret engine: the kernel body as plain XLA ops, no ref
        # simulation wrapper (see module docstring)
        outs = _epoch_math(operands, **statics)
    else:
        outs = pl.pallas_call(
            functools.partial(_epoch_kernel, n_in=len(operands), **statics),
            out_shape=out_shape,
            interpret=interp,
        )(*operands)

    if family == "fork":
        (pos_n, ti0, tse, tcnt, wfi, wfs, ri0, rse, f_sel, eacc, tacc,
         work, energy, err, fidx, tsens, hit) = outs
        return EpochOut(pos=pos_n, table=PRED.PCTable(ti0, tse, tcnt),
                        wf_i0=wfi, wf_sens=wfs, react_i0=ri0,
                        react_sens=rse, f_sel=f_sel, e_acc=eacc,
                        t_acc=tacc, work=work, energy=energy, err=err,
                        fidx=fidx, true_sens=tsens, hit_rate=hit)
    if family == "pc":
        (pos_n, ti0, tse, tcnt, wfi, wfs, f_sel, eacc, tacc, work, energy,
         err, fidx, tsens, hit) = outs
        return EpochOut(pos=pos_n, table=PRED.PCTable(ti0, tse, tcnt),
                        wf_i0=wfi, wf_sens=wfs, react_i0=None,
                        react_sens=None, f_sel=f_sel, e_acc=eacc,
                        t_acc=tacc, work=work, energy=energy, err=err,
                        fidx=fidx, true_sens=tsens, hit_rate=hit)
    (pos_n, ri0, rse, f_sel, eacc, tacc, work, energy, err, fidx,
     tsens) = outs
    return EpochOut(pos=pos_n, table=None, wf_i0=None, wf_sens=None,
                    react_i0=ri0, react_sens=rse, f_sel=f_sel, e_acc=eacc,
                    t_acc=tacc, work=work, energy=energy, err=err,
                    fidx=fidx, true_sens=tsens, hit_rate=None)
