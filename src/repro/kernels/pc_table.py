"""PCSTALL PC-table predict kernel (Pallas).

The paper's lookup path (§4.4, Fig 12): each wavefront indexes the table
with its next starting PC, per-WF (i0, sens) estimates are summed to the
CU/domain level, and I(f) is evaluated at every V/f state. On TPU this is
the per-step telemetry hot path of the DVFS runtime: one fused
gather + reduce + small matmul per V/f domain, entirely VMEM-resident
(the table is 128 entries — Table I: ~328 B/instance).

Grid: one program per CU. Blocks: the CU's WF indices + fallbacks in VMEM,
its table in VMEM, output row (n_freq,) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pc_table_kernel(tbl_i0_ref, tbl_sens_ref, tbl_cnt_ref, idx_ref,
                     fb_i0_ref, fb_sens_ref, freqs_ref, out_ref, *, n_wf: int):
    idx = idx_ref[0]                    # (WF,) int32 slots into this table
    ti0 = tbl_i0_ref[0]                 # (E,)
    tse = tbl_sens_ref[0]
    tcnt = tbl_cnt_ref[0]
    i0 = ti0[idx]                       # (WF,) gather in VMEM
    sens = tse[idx]
    hit = tcnt[idx] > 0.0
    i0 = jnp.where(hit, i0, fb_i0_ref[0])
    sens = jnp.where(hit, sens, fb_sens_ref[0])
    i0_sum = jnp.sum(i0)
    sens_sum = jnp.sum(sens)
    out_ref[0] = i0_sum + sens_sum * freqs_ref[...]


def pc_table_predict(tbl_i0: jax.Array, tbl_sens: jax.Array,
                     tbl_cnt: jax.Array, tid: jax.Array, idx: jax.Array,
                     fb_i0: jax.Array, fb_sens: jax.Array, freqs: jax.Array,
                     *, interpret: bool = True) -> jax.Array:
    """tbl_* (T,E); tid (CU,) table id per CU; idx/fb_* (CU,WF); freqs (F,).
    Returns I_pred (CU,F)."""
    CU, WF = idx.shape
    T, E = tbl_i0.shape
    F = freqs.shape[0]
    kernel = functools.partial(_pc_table_kernel, n_wf=WF)
    # expand tables per CU via the tid indirection in the index_map
    tid_host = tid  # static under jit? -> use gather outside for generality
    tbl_i0_cu = tbl_i0[tid]     # (CU,E) — tiny (128 floats/CU)
    tbl_sens_cu = tbl_sens[tid]
    tbl_cnt_cu = tbl_cnt[tid]
    return pl.pallas_call(
        kernel,
        grid=(CU,),
        in_specs=[
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((F,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, F), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((CU, F), jnp.float32),
        interpret=interpret,
    )(tbl_i0_cu.astype(jnp.float32), tbl_sens_cu.astype(jnp.float32),
      tbl_cnt_cu.astype(jnp.float32), idx.astype(jnp.int32),
      fb_i0.astype(jnp.float32), fb_sens.astype(jnp.float32),
      freqs.astype(jnp.float32))
