"""PCSTALL PC-table kernels (Pallas): fused predict and fused update.

Predict (paper §4.4, Fig 12): each wavefront indexes the table with its next
starting PC, per-WF (i0, sens) estimates are summed to the CU/domain level,
and I(f) is evaluated at every V/f state and clipped to the CU issue
capacity — the whole lookup -> reduce -> evaluate -> clip chain of
``simulate``'s ``_predict_instr`` path in one VMEM-resident kernel (the
table is 128 entries — Table I: ~328 B/instance). Grid: one program per CU.

Update: the epoch's per-WF (i0, sens) estimates are scattered back keyed by
starting PC. Pallas has no native scatter, so the kernel builds the per-slot
sums as a one-hot masked reduction over the table's wavefronts (N x E
compare + sum — N = cus_per_table * WF is a few thousand elements, VMEM
resident), then applies the collision-average + EMA blend in place. Grid:
one program per table instance.

``interpret`` defaults to the backend: interpreted on CPU, compiled on TPU;
the ``REPRO_PALLAS_INTERPRET`` env var overrides either way (see
``kernels._resolve_interpret``).

Power-regime sweeps: the ``freqs`` ladder is an ordinary array operand
(not a trace-time constant), so the engine passes the *traced* ladder it
builds from the ``PowerAxes`` endpoints (``power.freqs_ghz``) and one
compiled kernel serves every IVR regime of a grid; ``epoch_us`` and the
capacity clip already ride in as the packed scalar operand the same way.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret-mode resolution (incl. the REPRO_PALLAS_INTERPRET env
# override) is shared by every kernel generation; re-exported here for
# the pre-v2 import path
from repro.kernels import _resolve_interpret  # noqa: F401


def _pc_table_kernel(tbl_i0_ref, tbl_sens_ref, tbl_cnt_ref, idx_ref,
                     fb_i0_ref, fb_sens_ref, freqs_ref, scal_ref, out_ref, *,
                     n_wf: int):
    idx = idx_ref[0]                    # (WF,) int32 slots into this table
    ti0 = tbl_i0_ref[0]                 # (E,)
    tse = tbl_sens_ref[0]
    tcnt = tbl_cnt_ref[0]
    i0 = ti0[idx]                       # (WF,) gather in VMEM
    sens = tse[idx]
    hit = tcnt[idx] > 0.0
    i0 = jnp.where(hit, i0, fb_i0_ref[0])
    sens = jnp.where(hit, sens, fb_sens_ref[0])
    i0_sum = jnp.sum(i0)
    sens_sum = jnp.sum(sens)
    f = freqs_ref[...]
    epoch_us = scal_ref[0]              # traced sweep axes ride in as data
    cap_per_ghz = scal_ref[1]
    ipred = (i0_sum + sens_sum * f) * epoch_us
    # fused capacity clip (I <= cap*f*T*WF); cap <= 0 disables
    ipred = jnp.where(cap_per_ghz > 0.0,
                      jnp.clip(ipred, 0.0, cap_per_ghz * f * epoch_us * n_wf),
                      ipred)
    out_ref[0] = ipred


def pc_table_predict(tbl_i0: jax.Array, tbl_sens: jax.Array,
                     tbl_cnt: jax.Array, tid: jax.Array, idx: jax.Array,
                     fb_i0: jax.Array, fb_sens: jax.Array, freqs: jax.Array,
                     *, epoch_us=1.0, cap_per_ghz=0.0,
                     interpret: Optional[bool] = None) -> jax.Array:
    """tbl_* (T,E); tid (CU,) table id per CU; idx/fb_* (CU,WF); freqs (F,).
    Returns I_pred (CU,F) = clip((sum_wf i0 + sum_wf sens * f) * epoch_us),
    capacity-clipped when ``cap_per_ghz > 0`` (cap = cap*f*epoch_us*WF).

    ``epoch_us`` and ``cap_per_ghz`` may be Python floats or traced jnp
    scalars (the engine sweeps them as ``SimAxes`` grid axes): they enter
    the kernel as a packed (2,) operand, not as trace-time constants."""
    CU, WF = idx.shape
    T, E = tbl_i0.shape
    F = freqs.shape[0]
    kernel = functools.partial(_pc_table_kernel, n_wf=WF)
    scal = jnp.stack([jnp.asarray(epoch_us, jnp.float32),
                      jnp.asarray(cap_per_ghz, jnp.float32)])
    # expand tables per CU via the tid gather (tiny: 128 floats/CU)
    tbl_i0_cu = tbl_i0[tid]     # (CU,E)
    tbl_sens_cu = tbl_sens[tid]
    tbl_cnt_cu = tbl_cnt[tid]
    return pl.pallas_call(
        kernel,
        grid=(CU,),
        in_specs=[
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, E), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((1, WF), lambda c: (c, 0)),
            pl.BlockSpec((F,), lambda c: (0,)),
            pl.BlockSpec((2,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, F), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((CU, F), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(tbl_i0_cu.astype(jnp.float32), tbl_sens_cu.astype(jnp.float32),
      tbl_cnt_cu.astype(jnp.float32), idx.astype(jnp.int32),
      fb_i0.astype(jnp.float32), fb_sens.astype(jnp.float32),
      freqs.astype(jnp.float32), scal)


def _pc_table_update_kernel(tbl_i0_ref, tbl_sens_ref, tbl_cnt_ref, idx_ref,
                            i0_ref, sens_ref, ema_ref, out_i0_ref,
                            out_sens_ref, out_cnt_ref, *, entries: int):
    idx = idx_ref[0]                                    # (N,) slots
    # scatter-free per-slot accumulation: one-hot mask (N,E) + column sums
    slots = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], entries), 1)
    onehot = (idx[:, None] == slots).astype(jnp.float32)
    cnt = onehot.sum(axis=0)                            # (E,) updates/slot
    isum = (onehot * i0_ref[0][:, None]).sum(axis=0)
    ssum = (onehot * sens_ref[0][:, None]).sum(axis=0)
    inew = jnp.where(cnt > 0, isum / jnp.maximum(cnt, 1.0), 0.0)
    snew = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1.0), 0.0)
    tcnt = tbl_cnt_ref[0]
    fresh = (tcnt == 0.0) & (cnt > 0)
    ema = ema_ref[0]                    # traced sweep axis (table_ema)
    blend = jnp.where(fresh, 1.0, jnp.where(cnt > 0, ema, 0.0))
    out_i0_ref[0] = tbl_i0_ref[0] * (1.0 - blend) + inew * blend
    out_sens_ref[0] = tbl_sens_ref[0] * (1.0 - blend) + snew * blend
    out_cnt_ref[0] = tcnt + cnt


def pc_table_update(tbl_i0: jax.Array, tbl_sens: jax.Array,
                    tbl_cnt: jax.Array, idx: jax.Array, i0: jax.Array,
                    sens: jax.Array, *, ema=0.5,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused PC-table update. tbl_* (T,E); idx/i0/sens (T,N) grouped per
    table instance (N = wavefronts feeding that table, e.g.
    cus_per_table * WF with the contiguous CU->table mapping).

    Within-epoch collisions are averaged, then EMA-blended into the table
    (first touch replaces). ``ema`` may be a float or a traced jnp scalar
    (the ``table_ema`` sweep axis) — it enters the kernel as a (1,)
    operand. Returns the new (i0, sens, count) arrays — semantics
    identical to ``predictors.table_update``."""
    T, E = tbl_i0.shape
    Tn, N = idx.shape
    assert Tn == T, (Tn, T)
    kernel = functools.partial(_pc_table_update_kernel, entries=E)
    out = jax.ShapeDtypeStruct((T, E), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, E), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (t, 0)),
            pl.BlockSpec((1, N), lambda t: (t, 0)),
            pl.BlockSpec((1, N), lambda t: (t, 0)),
            pl.BlockSpec((1, N), lambda t: (t, 0)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, E), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (t, 0)),
        ],
        out_shape=[out, out, out],
        interpret=_resolve_interpret(interpret),
    )(tbl_i0.astype(jnp.float32), tbl_sens.astype(jnp.float32),
      tbl_cnt.astype(jnp.float32), idx.astype(jnp.int32),
      i0.astype(jnp.float32), sens.astype(jnp.float32),
      jnp.asarray(ema, jnp.float32).reshape(1))
