"""jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run in interpret mode (the TPU lowering path
is identical apart from ``interpret=False``); ``set_backend('tpu')`` flips
every wrapper to compiled mode on real hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pc_table as _pt
from repro.kernels import rwkv_chunk as _rc

# None = resolve from the actual backend lazily at first call (interpreted
# everywhere except real TPUs) — probing jax.default_backend() at import
# time would initialize backends before callers can configure jax
# (distributed.initialize, platform overrides). set_backend() overrides.
_INTERPRET: Optional[bool] = None


def set_backend(backend: str) -> None:
    global _INTERPRET
    _INTERPRET = backend != "tpu"


def _interpret() -> bool:
    if _INTERPRET is None:
        return jax.default_backend() != "tpu"
    return _INTERPRET


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128) -> jax.Array:
    """q (B,S,H,hd), k/v (B,S,Hkv,hd) with H % Hkv == 0. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, hd)
    vb = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, hd)
    out = _fa.flash_attention_bhsd(qb, kb, vb, causal=causal, window=window,
                                   blk_q=blk_q, blk_k=blk_k,
                                   interpret=_interpret())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).astype(q.dtype)


@jax.jit
def pc_table_predict(tbl_i0, tbl_sens, tbl_cnt, tid, idx, fb_i0, fb_sens,
                     freqs, *, epoch_us=1.0, cap_per_ghz=0.0):
    # epoch_us / cap_per_ghz are traced operands (sweep axes), not cache
    # keys: one executable serves every grid point.
    return _pt.pc_table_predict(tbl_i0, tbl_sens, tbl_cnt, tid, idx,
                                fb_i0, fb_sens, freqs, epoch_us=epoch_us,
                                cap_per_ghz=cap_per_ghz, interpret=_interpret())


@jax.jit
def pc_table_update(tbl_i0, tbl_sens, tbl_cnt, idx, i0, sens, *, ema=0.5):
    return _pt.pc_table_update(tbl_i0, tbl_sens, tbl_cnt, idx, i0, sens,
                               ema=ema, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv_chunked(r, k, v, w, u, *, chunk: int = 128):
    return _rc.rwkv_chunked(r, k, v, w, u, chunk=chunk, interpret=_interpret())
