"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,S,H,hd), k/v (B,S,Hkv,hd) — full-softmax reference (fp32)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, S, Hkv, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32))
    scores = scores / (hd ** 0.5)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = kj <= qi if causal else jnp.ones((S, S), bool)
    if window:
        mask = mask & (kj > qi - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def pc_table_predict_ref(table_i0: jax.Array, table_sens: jax.Array,
                         table_count: jax.Array, tid: jax.Array,
                         idx: jax.Array, fb_i0: jax.Array, fb_sens: jax.Array,
                         freqs: jax.Array, *, epoch_us: float = 1.0,
                         cap_per_ghz: float = 0.0) -> jax.Array:
    """PCSTALL lookup + per-CU aggregation + I(f) evaluation (+ optional
    capacity clip). table_* (T,E); tid (CU,); idx/fb_* (CU,WF); freqs (F,).
    Returns I_pred (CU,F) = clip(sum_wf (i0 + sens*f) * epoch_us)."""
    i0 = table_i0[tid[:, None], idx]
    sens = table_sens[tid[:, None], idx]
    hit = table_count[tid[:, None], idx] > 0
    i0 = jnp.where(hit, i0, fb_i0)
    sens = jnp.where(hit, sens, fb_sens)
    n_wf = idx.shape[1]
    ipred = (i0.sum(-1)[:, None]
             + sens.sum(-1)[:, None] * freqs[None, :]) * epoch_us
    if cap_per_ghz > 0.0:
        ipred = jnp.clip(ipred, 0.0,
                         cap_per_ghz * freqs[None, :] * epoch_us * n_wf)
    return ipred.astype(jnp.float32)


def pc_table_update_ref(table_i0: jax.Array, table_sens: jax.Array,
                        table_count: jax.Array, idx: jax.Array,
                        i0: jax.Array, sens: jax.Array, *, ema: float = 0.5):
    """Oracle for the fused update kernel: collision-averaged scatter + EMA
    blend, per table instance. table_* (T,E); idx/i0/sens (T,N)."""
    T, E = table_i0.shape
    onehot = (idx[..., None] == jnp.arange(E)[None, None, :]) \
        .astype(jnp.float32)                                # (T,N,E)
    cnt = onehot.sum(1)
    isum = (onehot * i0[..., None]).sum(1)
    ssum = (onehot * sens[..., None]).sum(1)
    inew = jnp.where(cnt > 0, isum / jnp.maximum(cnt, 1.0), 0.0)
    snew = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1.0), 0.0)
    fresh = (table_count == 0) & (cnt > 0)
    blend = jnp.where(fresh, 1.0, jnp.where(cnt > 0, ema, 0.0))
    return (table_i0 * (1 - blend) + inew * blend,
            table_sens * (1 - blend) + snew * blend,
            table_count + cnt)


def rwkv_chunk_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, S0: jax.Array):
    """Exact RWKV6 recurrence (scan), one head.
    r,k,v,w (T,hd) fp32; u (hd,); S0 (hd,hd). Returns (y (T,hd), S_T)."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        a = jnp.outer(kt, vt)
        y = rt @ (S + u[:, None] * a)
        return wt[:, None] * S + a, y
    S_T, y = jax.lax.scan(step, S0, (r, k, v, w))
    return y, S_T
