"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,S,H,hd), k/v (B,S,Hkv,hd) — full-softmax reference (fp32)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, S, Hkv, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32))
    scores = scores / (hd ** 0.5)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = kj <= qi if causal else jnp.ones((S, S), bool)
    if window:
        mask = mask & (kj > qi - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def pc_table_predict_ref(table_i0: jax.Array, table_sens: jax.Array,
                         table_count: jax.Array, tid: jax.Array,
                         idx: jax.Array, fb_i0: jax.Array, fb_sens: jax.Array,
                         freqs: jax.Array) -> jax.Array:
    """PCSTALL lookup + per-CU aggregation + I(f) evaluation.
    table_* (T,E); tid (CU,); idx/fb_* (CU,WF); freqs (F,).
    Returns I_pred (CU,F) = sum_wf (i0 + sens*f)."""
    i0 = table_i0[tid[:, None], idx]
    sens = table_sens[tid[:, None], idx]
    hit = table_count[tid[:, None], idx] > 0
    i0 = jnp.where(hit, i0, fb_i0)
    sens = jnp.where(hit, sens, fb_sens)
    return (i0.sum(-1)[:, None]
            + sens.sum(-1)[:, None] * freqs[None, :]).astype(jnp.float32)


def rwkv_chunk_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, S0: jax.Array):
    """Exact RWKV6 recurrence (scan), one head.
    r,k,v,w (T,hd) fp32; u (hd,); S0 (hd,hd). Returns (y (T,hd), S_T)."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        a = jnp.outer(kt, vt)
        y = rt @ (S + u[:, None] * a)
        return wt[:, None] * S + a, y
    S_T, y = jax.lax.scan(step, S0, (r, k, v, w))
    return y, S_T
