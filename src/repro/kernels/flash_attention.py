"""Flash attention Pallas TPU kernel (online softmax, causal/SWA, GQA).

TPU-native tiling: the MXU wants 128-aligned matmul dims, so default block
sizes are (blk_q=128, blk_k=128) with the head dim padded to a multiple of
128 by the wrapper when needed. Grid is (B*H, nq, nk) with the kv axis
innermost ('arbitrary' semantics — sequential accumulation into VMEM
scratch); q/k/v tiles stream HBM->VMEM per BlockSpec.

Validated in interpret mode against ``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, blk_q: int, blk_k: int, n_k: int,
                  causal: bool, window: int):
    i_q = pl.program_id(1)
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (blk_q, hd)
    k = k_ref[0].astype(jnp.float32)              # (blk_k, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = i_q * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    cols = i_k * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        mask = mask & (cols <= rows)
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (blk_q,)
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) -> exp(0) otherwise)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.maximum(m_prev - m_new, -80.0))
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(i_k == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         blk_q: int = 128, blk_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """q (BH, S, hd); k/v (BH, S, hd) — kv already expanded to q heads.
    Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    n_q, n_k = S // blk_q, S // blk_k
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k, n_k=n_k,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
