"""Trace-dataset generator: ``run_grid`` as a labeled-data factory.

One batched sweep over workload zoo x seeds x epoch granularities — the
same embarrassingly-parallel dispatch every figure uses — produces, per
run, the oracle mechanism's trace (labels) and the PCSTALL trace (hit
telemetry). From those this module reconstructs, offline and causally,
the per-epoch feature vector the deployed hook computes online
(``models.FEATURE_NAMES``; the online counterpart is
``learn.mechanism.epoch_features``).

Offline/online feature bridge
-----------------------------
The oracle trace does not record carry state, so three features are
reconstructed rather than replayed; each is causal (epoch ``t`` uses only
epochs ``< t`` plus engine init constants) and each approximation is
deliberate:

* ``react_i0/react_sens`` — the learned update hook maintains these as an
  EMA (``models.REACT_BETA``) of the exact per-epoch fork-linear digest.
  Offline, ``sens`` comes from the trace's exact ``true_sens`` channel
  and ``i0`` from ``work/T - sens * f_sel``; on the fork row these
  coincide with the digest up to the fork's capacity/transition
  nonlinearity, so the recursion matches deployment closely.
* ``pc_i0/pc_sens`` — the online values are WF-summed PC-table lookups.
  Offline we run the table's EMA (``table_ema``) over the CU-level
  estimates instead of per-entry scatters: a CU-aggregate proxy of the
  same statistic, seeded at the engine's per-WF init (``1.2/0.8 * n_wf``).
* ``hit`` — the trace's ``hit_rate`` channel is epoch-scalar (mean over
  CU and WF); it is broadcast per CU, where online it is the per-CU mean.

``f_prev`` and ``pbar`` are exact given the trace (the trajectory's
frequency choices and the energy channel + the engine's documented
warm-start constants).

Behavior-policy coverage
------------------------
Each run contributes TWO trajectories: the oracle's (labels: the
oracle's actual frequency choices — the tentpole's label contract) and
PCSTALL's (labels: the objective mirror :func:`select_fidx` applied to
the realized next-epoch linear — what the greedy oracle would choose in
that state). Training only on oracle trajectories looks better offline
but fails closed-loop: the policy-coupled features (``f_prev``,
``pbar``) then only cover the oracle's operating distribution, and a
deployed head that extrapolates there feeds back into its own frequency
choices (the standard imitation-learning distribution-shift failure —
observed as pinning f_max before this augmentation). The PCSTALL
trajectories anchor those features on a realistic non-oracle policy, so
the deployed closed loop stays in-distribution. ``data["policy"]``
records the source trajectory (0 = oracle, 1 = pcstall) per row.

Determinism: same ``DatasetConfig`` -> bitwise-identical npz (the grid
dispatch is deterministic, the reconstruction is pure numpy, and
``data.pipeline.export_npz`` writes canonically). Train/val splits are
by RUN (workload x seed x granularity) via ``pipeline.train_val_split``
so validation measures held-out traces, not interleaved epochs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple, Union

import numpy as np

from repro.core import power as PWR
from repro.core import simulate as SIM
from repro.core.simulate import SimConfig
from repro.core.sweep import run_grid
from repro.core.workloads import get_workload
from repro.data import pipeline as PIPE
from repro.learn import models as LM


@dataclass(frozen=True)
class DatasetConfig:
    """The labeled-data factory's sweep + reconstruction settings."""
    workloads: Tuple[str, ...] = ("comd", "hpgmg", "lulesh", "minife",
                                  "xsbench", "hacc", "pennant", "dgemm")
    seeds: Tuple[int, ...] = (0, 1)
    epoch_us: Tuple[float, ...] = (1.0, 10.0)
    n_cu: int = 32
    n_epochs: int = 240
    warmup: int = 24            # epochs dropped while EMAs burn in
    objective: str = "ed2p"
    val_frac: float = 0.25
    seed: int = 0               # split stream seed
    # Sweep engine mode: the factory's run_grid dispatch inherits the
    # fused-kernel grid path ("v2") for free. Determinism holds per
    # config — the jnp and v2 engines produce different (each internally
    # bitwise-reproducible) trace streams, so the engine mode is part of
    # a dataset's identity like any other field here.
    use_pallas: Union[bool, str] = False

    def sim(self) -> SimConfig:
        return SimConfig(n_cu=self.n_cu, n_epochs=self.n_epochs,
                         objective=self.objective,
                         use_pallas=self.use_pallas)


def _run_features(otr: Dict[str, np.ndarray], hit: np.ndarray, T: float,
                  sim: SimConfig, F: np.ndarray, e0: float, t0: float
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One run's causal feature/target reconstruction.

    ``otr``: oracle trace (epoch-leading arrays), ``hit``: PCSTALL
    hit_rate channel (E,). Returns ``(x (E,CU,F), y (E,CU,2),
    fidx (E,CU))`` over ALL epochs — the caller drops warmup."""
    work = np.asarray(otr["work"], np.float64)         # (E, CU)
    energy = np.asarray(otr["energy"], np.float64)
    fidx = np.asarray(otr["fidx"], np.int64)
    sens = np.asarray(otr["true_sens"], np.float64)
    E, CU = work.shape
    f_sel = F[fidx]
    i0_est = work / T - sens * f_sel

    beta, ema = LM.REACT_BETA, sim.table_ema
    pc_i0 = np.full(CU, 1.2 * sim.n_wf)
    pc_sens = np.full(CU, 0.8 * sim.n_wf)
    react_i0 = np.full(CU, 50.0)
    react_sens = np.full(CU, 30.0)
    f_prev = np.full(CU, PWR.F_STATIC)
    e_acc, t_acc = np.full(CU, e0), t0

    x = np.zeros((E, CU, LM.N_FEATURES))
    for t in range(E):
        pbar = e_acc / max(t_acc, 1e-3)
        x[t] = np.stack([pc_i0, pc_sens, react_i0, react_sens,
                         f_prev, pbar, np.full(CU, hit[t])], axis=-1)
        pc_i0 = (1.0 - ema) * pc_i0 + ema * i0_est[t]
        pc_sens = (1.0 - ema) * pc_sens + ema * sens[t]
        react_i0 = (1.0 - beta) * react_i0 + beta * i0_est[t]
        react_sens = (1.0 - beta) * react_sens + beta * sens[t]
        f_prev = f_sel[t]
        e_acc = e_acc + energy[t]
        t_acc = t_acc + T
    y = np.stack([i0_est, sens], axis=-1)
    return (x.astype(np.float32), y.astype(np.float32),
            fidx.astype(np.int32))


def generate_dataset(cfg: DatasetConfig = DatasetConfig()
                     ) -> Tuple[Dict[str, np.ndarray], dict]:
    """Run the factory sweep and reconstruct the labeled dataset.

    Returns ``(arrays, meta)`` ready for :func:`save_dataset`:

    * ``x (N, n_features)`` raw features, ``y (N, 2)`` regression targets
      ``(i0_rate, sens_rate)``, ``fidx (N,)`` the (greedy-)oracle
      frequency label, ``t_us (N,)`` the row's epoch length,
      ``run (N,)`` run id, ``policy (N,)`` source trajectory,
    * ``train_runs``/``val_runs`` — the seeded by-run split (both policy
      trajectories of a run land on the same side — no leakage).
    """
    sim = cfg.sim()
    progs = {w: get_workload(w) for w in cfg.workloads}
    grid = run_grid(progs, sim, {"epoch_us": list(cfg.epoch_us)},
                    ("pcstall", "oracle"), seeds=list(cfg.seeds))
    carry0 = SIM.init_carry(next(iter(progs.values())).n_blocks,
                            sim.static_part())
    e0, t0 = float(carry0.e_acc[0]), float(carry0.t_acc)
    F = np.asarray(PWR.freqs_ghz(sim.power), np.float64)
    # the selection-mirror context for the pcstall-trajectory labels
    meta_sel = {"freqs_ghz": [float(f) for f in F],
                "cap_per_ghz": sim.cap_per_ghz, "n_wf": sim.n_wf,
                "objective": cfg.objective}
    pbar_col = LM.FEATURE_NAMES.index("pbar")

    xs, ys, fs, ts, rs, ps, runs = [], [], [], [], [], [], []
    for T in cfg.epoch_us:
        for w in cfg.workloads:
            for si, seed in enumerate(cfg.seeds):
                point = grid[(T,)][w]
                run_id = len(runs)
                runs.append({"workload": w, "seed": int(seed),
                             "epoch_us": float(T)})
                hit = np.asarray(point["pcstall"]["hit_rate"][si],
                                 np.float64)
                for pol, mech in ((0, "oracle"), (1, "pcstall")):
                    tr = {k: np.asarray(v[si])
                          for k, v in point[mech].items()}
                    x, y, fidx = _run_features(tr, hit, float(T), sim,
                                               F, e0, t0)
                    x, y, fidx = (a[cfg.warmup:] for a in (x, y, fidx))
                    n = x.shape[0] * x.shape[1]
                    x, y = x.reshape(n, -1), y.reshape(n, -1)
                    if pol == 1:
                        # greedy-oracle label for the behavior trajectory
                        fidx = select_fidx(y[:, 0], y[:, 1],
                                           x[:, pbar_col],
                                           np.full(n, T), meta_sel)
                    xs.append(x)
                    ys.append(y)
                    fs.append(fidx.reshape(n))
                    ts.append(np.full(n, T, np.float32))
                    rs.append(np.full(n, run_id, np.int32))
                    ps.append(np.full(n, pol, np.int8))
    tr, va = PIPE.train_val_split(len(runs), val_frac=cfg.val_frac,
                                  seed=cfg.seed)
    data = {"x": np.concatenate(xs), "y": np.concatenate(ys),
            "fidx": np.concatenate(fs), "t_us": np.concatenate(ts),
            "run": np.concatenate(rs), "policy": np.concatenate(ps),
            "train_runs": tr, "val_runs": va}
    meta = {"feature_names": list(LM.FEATURE_NAMES),
            "target_names": list(LM.TARGET_NAMES),
            "workloads": list(cfg.workloads), "seeds": list(cfg.seeds),
            "epoch_us": list(cfg.epoch_us), "runs": runs,
            "n_cu": sim.n_cu, "n_wf": sim.n_wf,
            "n_epochs": cfg.n_epochs, "warmup": cfg.warmup,
            "objective": cfg.objective, "table_ema": sim.table_ema,
            "cap_per_ghz": sim.cap_per_ghz,
            "react_beta": LM.REACT_BETA, "split_seed": cfg.seed,
            "val_frac": cfg.val_frac,
            "freqs_ghz": [float(f) for f in F],
            "e_acc0": e0, "t_acc0": t0, "power": "default"}
    return data, meta


def save_dataset(path, data: Dict[str, np.ndarray], meta: dict):
    """Canonical npz export (bitwise-reproducible; see ``pipeline``)."""
    return PIPE.export_npz(path, data, meta)


def load_dataset(path) -> Tuple[Dict[str, np.ndarray], dict]:
    return PIPE.load_npz(path)


def split_masks(data: Dict[str, np.ndarray]
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Row masks for the by-run train/val split."""
    return (np.isin(data["run"], data["train_runs"]),
            np.isin(data["run"], data["val_runs"]))


def select_fidx(i0: np.ndarray, sens: np.ndarray, pbar: np.ndarray,
                t_us: np.ndarray, meta: dict) -> np.ndarray:
    """Offline mirror of the engine's frequency selection at
    ``cus_per_domain=1`` (the factory configs'): lower a per-row
    ``(i0, sens)`` linear model through ``predict_instr``'s clipping and
    ``_select_freq``'s objective cost, vectorized over rows. Metric-only
    — the deployed hook goes through the real traced path; this exists
    to score frequency-choice agreement without a dispatch per row."""
    F = np.asarray(meta["freqs_ghz"], np.float64)
    cap, n_wf = meta["cap_per_ghz"], meta["n_wf"]
    w_pbar, use_rate, capf = np.asarray(
        SIM.objective_weights(meta["objective"]), np.float64)
    T = np.asarray(t_us, np.float64)[:, None]
    I = (np.asarray(i0, np.float64)[:, None]
         + np.asarray(sens, np.float64)[:, None] * F[None, :]) * T
    cap_row = cap * F[None, :] * T * n_wf
    I = np.clip(I, 0.0, cap_row)
    act = I / cap_row
    p = np.asarray(PWR.power(F[None, :], act), np.float64)
    I_sum = np.maximum(I, 1e-3)
    denom = I_sum if use_rate > 0.0 else np.ones_like(I_sum)
    infeasible = I_sum < capf * I_sum[:, -1:]
    cost = (p + w_pbar * np.asarray(pbar, np.float64)[:, None]) / denom \
        + 1e9 * infeasible
    return np.argmin(cost, axis=-1).astype(np.int32)


def choice_accuracy(pred_y: np.ndarray, data: Dict[str, np.ndarray],
                    meta: dict, mask: np.ndarray) -> float:
    """Fraction of rows where the predicted ``(i0, sens)`` model selects
    the oracle's frequency index, over ``mask``'s rows. ``pbar`` is
    feature column 5 — exact, so the score isolates prediction quality."""
    pbar_col = list(meta["feature_names"]).index("pbar")
    f = select_fidx(pred_y[mask, 0], pred_y[mask, 1],
                    data["x"][mask, pbar_col], data["t_us"][mask], meta)
    return float(np.mean(f == data["fidx"][mask]))
