"""Learned-predictor subsystem: trace-driven training of ``family="pc"``
DVFS mechanisms.

The pipeline is train -> freeze -> register -> sweep:

1. ``learn.dataset`` runs ``run_grid`` over workloads x seeds x epoch
   granularities as a labeled-data factory (oracle choices are the
   labels) with deterministic by-run train/val splits;
2. ``learn.models`` + ``learn.train`` fit a linear I(f) head (Ilager et
   al., arXiv:2004.08177) and a tiny MLP with the seed's cosine-LR AdamW,
   folding feature normalization into the frozen raw-space weights;
3. ``learn.mechanism`` registers the frozen weights as ``learned_lin`` /
   ``learned_mlp`` pc-family specs (ParamHook: value-keyed, audit-clean,
   zero engine edits) that sweep like any builtin.

``python -m repro.learn`` runs the miniature end-to-end pipeline (the CI
learn lane's entry point).
"""
from repro.learn.dataset import (DatasetConfig, choice_accuracy,
                                 generate_dataset, load_dataset,
                                 save_dataset, select_fidx, split_masks)
from repro.learn.mechanism import (LEARNED_AXES, epoch_features,
                                   learned_predict, learned_update,
                                   make_learned_spec, register_learned)
from repro.learn.models import (APPLY, FEATURE_NAMES, INIT, N_FEATURES,
                                N_TARGETS, REACT_BETA, REACT_COLS,
                                TARGET_NAMES, apply_model, fold_norm,
                                init_linear, init_mlp, kind_of,
                                linear_apply, mlp_apply, predict_targets)
from repro.learn.train import (default_tc, fit, load_weights,
                               make_train_step, norm_stats,
                               reactive_choice_baseline, save_weights)

__all__ = [
    "DatasetConfig", "choice_accuracy", "generate_dataset", "load_dataset",
    "save_dataset", "select_fidx", "split_masks",
    "LEARNED_AXES", "epoch_features", "learned_predict", "learned_update",
    "make_learned_spec", "register_learned",
    "APPLY", "FEATURE_NAMES", "INIT", "N_FEATURES", "N_TARGETS",
    "REACT_BETA", "REACT_COLS", "TARGET_NAMES", "apply_model",
    "fold_norm", "init_linear", "init_mlp", "kind_of", "linear_apply",
    "mlp_apply", "predict_targets",
    "default_tc", "fit", "load_weights", "make_train_step", "norm_stats",
    "reactive_choice_baseline", "save_weights",
]
