"""End-to-end learned-predictor pipeline CLI (the CI learn lane).

    PYTHONPATH=src python -m repro.learn --mini --steps 300 --out /tmp/learn

Generates a (miniature) factory dataset, trains the requested head(s),
freezes + registers the weights, and proves the deployment contract by
dispatching the registered spec through an unmodified ``run_grid`` —
asserting the fork-family compile bound and dedup row accounting on the
way. Exits nonzero on any violated invariant, so the lane is a real
check, not a smoke."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.core import mechanisms as MECH
from repro.core import sweep as SW
from repro.learn import dataset as LDS
from repro.learn import mechanism as LMECH
from repro.learn import train as LTR


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.learn")
    ap.add_argument("--mini", action="store_true",
                    help="miniature dataset (2 workloads x 1 seed, 8 CUs)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--kind", choices=("linear", "mlp", "both"),
                    default="linear")
    ap.add_argument("--out", type=Path, default=Path("learn_artifacts"))
    args = ap.parse_args(argv)

    cfg = LDS.DatasetConfig()
    if args.mini:
        cfg = LDS.DatasetConfig(workloads=("comd", "xsbench"), seeds=(0,),
                                epoch_us=(1.0,), n_cu=8, n_epochs=120,
                                warmup=16, val_frac=0.25)
    data, meta = LDS.generate_dataset(cfg)
    LDS.save_dataset(args.out / "dataset.npz", data, meta)
    _, val_mask = LDS.split_masks(data)
    if not val_mask.any():       # mini split may hold out zero runs
        val_mask = ~val_mask
    report = {"rows": int(data["x"].shape[0]),
              "runs": len(meta["runs"]),
              "reactive_choice_acc": LTR.reactive_choice_baseline(
                  data, meta, val_mask)}

    kinds = ("linear", "mlp") if args.kind == "both" else (args.kind,)
    for kind in kinds:
        params, curves = LTR.fit(data, meta, kind=kind, steps=args.steps)
        assert curves["probe"][-1] < curves["probe"][0], \
            f"{kind}: probe loss did not decrease: {curves['probe']}"
        LTR.save_weights(args.out / f"weights_{kind}.npz", params,
                         extra_meta={"steps": args.steps})
        name = "learned_lin" if kind == "linear" else "learned_mlp"
        spec = LMECH.register_learned(name, params, allow_override=True)

        # deployment contract: unmodified grid dispatch, bounded compiles,
        # dedup accounting (the learned pc spec consumes every axis)
        SW.reset_counters()
        from repro.core.workloads import get_workload
        progs = {w: get_workload(w) for w in cfg.workloads[:2]}
        sim = cfg.sim()
        grid = SW.run_grid(progs, sim, {"objective": ["ed2p", "deadline05"]},
                           ("crisp", "pcstall", spec.name))
        fork_compiles = sum(v for k, v in SW.TRACE_COUNTS.items()
                            if k == "grid_forks")
        assert fork_compiles <= 2, SW.TRACE_COUNTS
        W, G = len(progs), 2
        assert SW.DISPATCH_ROWS[f"grid_{spec.name}"] == W * G, \
            dict(SW.DISPATCH_ROWS)
        tr = grid[("ed2p",)][cfg.workloads[0]][spec.name]
        report[kind] = {
            "first_loss": curves["probe"][0],
            "final_loss": curves["probe"][-1],
            "val_mse": curves.get("val_mse"),
            "val_choice_acc": curves.get("val_choice_acc"),
            "deployed_mean_f": float(
                np.take(meta["freqs_ghz"], tr["fidx"].astype(int)).mean()),
        }
        MECH.unregister(name)

    (args.out / "report.json").write_text(json.dumps(report, indent=2))
    print(json.dumps(report, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
