"""Deploy frozen learned predictors as ``family="pc"`` MechanismSpecs.

This is the PR 4 hook API's design claim exercised for real: a genuinely
new predictor family — weights learned offline from oracle traces — is
registered with ZERO edits to the engine or the sweep layer. The frozen
numpy weights ride a :class:`repro.core.mechanisms.ParamHook`, so

* inside the scan body they are traced-in closure constants (one matmul
  per epoch — no parameter operands, no pytree plumbing),
* specs compare by weight VALUE: reloading the same artifact re-hits
  every compiled executable, retraining compiles a fresh specialized
  family, and neither ever touches the shared builtin fork family,
* registration runs the standard axis-liveness audit — the hooks below
  genuinely consume every traced axis, and the auditor verifies that
  from the jaxpr rather than trusting the declaration.

The predict hook computes ``models.FEATURE_NAMES`` online from exactly
the carry/context view every builtin predictor sees (the engine
maintains the PC table for custom pc-family specs), applies the frozen
head, and lowers the predicted ``(i0, sens)`` through the public
``predict_instr``. The update hook maintains ``carry.react_*`` as an EMA
of the exact fork-linear digest — the same recursion the dataset
reconstructs offline, keeping train-time and deploy-time features
aligned.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import mechanisms as MECH
from repro.core import power as PWR
from repro.core import predictors as PRED
from repro.core import simulate as SIM
from repro.learn import models as LM

# Learned pc-family specs consume every traced axis: the engine-imposed
# floor for pc (execution model + mask + power + objective + table EMA)
# is already the full set, and the hooks add nothing dead.
LEARNED_AXES = MECH.SIM_AXES_FIELDS


def epoch_features(carry, ctx, st, ax) -> jnp.ndarray:
    """(CU, n_features) online feature matrix — the deployed counterpart
    of ``dataset._run_features`` (same names, order and semantics)."""
    tid = jnp.arange(st.n_cu) // st.cus_per_table
    idx = PRED.table_index(ctx.blk, st.entries, st.offset_blocks)
    i0_wf, s_wf, hit = PRED.table_lookup(carry.table, tid, idx,
                                         carry.wf_i0, carry.wf_sens)
    pbar = carry.e_acc / jnp.maximum(carry.t_acc, 1e-3)
    return jnp.stack([i0_wf.sum(-1), s_wf.sum(-1),
                      carry.react_i0, carry.react_sens,
                      carry.f_prev, pbar, hit.mean(-1)], axis=-1)


def learned_predict(carry, ctx, st, ax, *, params) -> jnp.ndarray:
    """Frozen residual head over the online features (reactive digest +
    learned correction — ``models.predict_targets``), lowered to the
    capacity-clipped (CU, n_freqs) prediction the controller consumes."""
    out = LM.predict_targets(params, epoch_features(carry, ctx, st, ax))
    return SIM.predict_instr(out[:, 0], out[:, 1], st, ax)


def learned_update(counters, f_sel, I_f, carry, ctx, st, ax):
    """EMA digest of the exact fork linear into ``carry.react_*`` (the
    react_i0/react_sens features; beta = ``models.REACT_BETA``)."""
    F = PWR.freqs_ghz(ax.power, st.power.n_freqs)
    T = ax.epoch_us
    sens = (I_f[:, -1] - I_f[:, 0]) / ((F[-1] - F[0]) * T)
    i0 = I_f[:, 0] / T - sens * F[0]
    b = LM.REACT_BETA
    return ((1.0 - b) * carry.react_i0 + b * i0,
            (1.0 - b) * carry.react_sens + b * sens)


def make_learned_spec(name: str, params: Dict[str, np.ndarray], *,
                      label: str = "", color: Optional[str] = None,
                      hit_telemetry: bool = True) -> MECH.MechanismSpec:
    """Wrap frozen weights into an (unregistered) pc-family spec."""
    kind = LM.kind_of(params)
    return MECH.MechanismSpec(
        name, "pc", exec_axes=LEARNED_AXES,
        label=label or f"Learned ({kind})", color=color,
        hit_telemetry=hit_telemetry,
        predict=MECH.ParamHook(learned_predict, params),
        update=learned_update)


def register_learned(name: str, params: Dict[str, np.ndarray], *,
                     label: str = "", color: Optional[str] = None,
                     allow_override: bool = False) -> MECH.MechanismSpec:
    """Register a frozen model under ``name`` (audited like any custom
    spec); returns the spec for direct ``run_grid``/``run_sim`` use."""
    return MECH.register(
        make_learned_spec(name, params, label=label, color=color),
        allow_override=allow_override)
