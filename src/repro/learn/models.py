"""Predictor models for the learned DVFS mechanisms.

Two deliberately tiny heads map a per-CU feature vector to the per-CU
``(i0, sens)`` linear-rate pair the engine's ``predict_instr`` lowering
consumes — the same representation every builtin predictor speaks:

* ``linear`` — the Ilager et al. starting point (arXiv:2004.08177): one
  affine map from runtime telemetry to the I(f) model. 16 weights; the
  frozen artifact is a single matmul inside the scan body.
* ``mlp`` — one tanh hidden layer, for the nonlinear phase structure the
  linear head cannot express (DSO-style static+dynamic feature fusion,
  arXiv:2407.13096, motivates the mixed feature set below).

Both heads are residual over the reactive EMA digest — the deployed
prediction is ``react_(i0, sens) + net(features)`` (see
:func:`predict_targets`) so zero weights reproduce the reactive
baseline exactly and training only learns where the PC-table features
beat reaction.

Training happens in standardized feature/target space (AdamW behaves far
better there), but the deployed hook must be a pure function of RAW
engine features — so :func:`fold_norm` folds the standardization affine
into the weights at freeze time and the frozen artifact needs no side
statistics.

The feature vector (order is the contract between ``learn.dataset``
offline reconstruction and ``learn.mechanism`` online computation):

====  ===========  ======================================================
 idx   name         per-CU semantics
====  ===========  ======================================================
 0     pc_i0        PC-table i0 lookup at the current blocks, WF-summed
 1     pc_sens      PC-table sens lookup, WF-summed
 2     react_i0     EMA(beta=REACT_BETA) of the exact fork-linear i0
 3     react_sens   EMA of the exact fork-linear sensitivity
 4     f_prev       previous epoch's chosen frequency (GHz)
 5     pbar         online average power e_acc / t_acc (the Pbar term)
 6     hit          PC-table hit rate (stall/hit telemetry)
====  ===========  ======================================================
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

FEATURE_NAMES = ("pc_i0", "pc_sens", "react_i0", "react_sens",
                 "f_prev", "pbar", "hit")
N_FEATURES = len(FEATURE_NAMES)
TARGET_NAMES = ("i0_rate", "sens_rate")
N_TARGETS = len(TARGET_NAMES)

# EMA weight of the per-epoch exact fork-linear digest maintained in
# carry.react_* by the learned update hook; learn.dataset reproduces the
# same recursion offline so train-time and deploy-time features agree.
REACT_BETA = 0.5

Params = Dict[str, np.ndarray]


def init_linear(seed: int = 0) -> Params:
    """Near-zero init: the folded-norm output starts at the target mean."""
    rng = np.random.default_rng((seed, N_FEATURES))
    w = rng.standard_normal((N_FEATURES, N_TARGETS)).astype(np.float32)
    return {"w": 0.01 * w, "b": np.zeros((N_TARGETS,), np.float32)}


def linear_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ jnp.asarray(params["w"]) + jnp.asarray(params["b"])


def init_mlp(seed: int = 0, hidden: int = 24) -> Params:
    rng = np.random.default_rng((seed, hidden))
    w1 = rng.standard_normal((N_FEATURES, hidden)).astype(np.float32)
    w2 = rng.standard_normal((hidden, N_TARGETS)).astype(np.float32)
    return {"w1": w1 * np.sqrt(2.0 / N_FEATURES, dtype=np.float32),
            "b1": np.zeros((hidden,), np.float32),
            "w2": 0.01 * w2,
            "b2": np.zeros((N_TARGETS,), np.float32)}


def mlp_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(x @ jnp.asarray(params["w1"]) + jnp.asarray(params["b1"]))
    return h @ jnp.asarray(params["w2"]) + jnp.asarray(params["b2"])


def kind_of(params: Params) -> str:
    """Infer the head from the parameter keys (the frozen artifact is a
    flat array dict; the keys are disjoint between heads by design)."""
    return "linear" if "w" in params else "mlp"


def apply_model(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch on parameter keys — a Python-level (trace-static) branch."""
    return (linear_apply if kind_of(params) == "linear" else mlp_apply)(
        params, x)


APPLY = {"linear": linear_apply, "mlp": mlp_apply}
INIT = {"linear": init_linear, "mlp": init_mlp}

# Residual head contract: the network predicts a CORRECTION to the
# reactive EMA digest, not (i0, sens) from scratch. The react features
# are already an unbiased one-step predictor (the reactive baseline
# scores ~0.84 frequency-choice agreement on factory datasets); asking a
# single shared head to regress absolute rates instead makes it smooth
# across workloads and lose per-workload calibration — observed as large
# offline sens bias on individual workloads that the objective lowering
# amplifies into wrong frequency picks. With the residual form, zero
# weights ARE the reactive baseline, weight decay anchors deployment
# there, and training only spends capacity where the PC-table features
# genuinely improve on reaction (anticipating phase changes the EMA
# lags). Columns follow TARGET_NAMES order: (react_i0, react_sens).
REACT_COLS = (FEATURE_NAMES.index("react_i0"),
              FEATURE_NAMES.index("react_sens"))

# Trust region on the learned correction: |delta| <= TRUST * |react|.
# The react digest is the one feature pair whose offline reconstruction
# is EXACT (the update hook runs the identical recursion online); the
# others are proxies (pc table) or policy-coupled (f_prev, pbar). The
# clamp bounds how far a proxy-feature misprediction can push the
# deployed closed loop from the reactive envelope: predictions live in
# [1-TRUST, 1+TRUST] x react, so the learned mechanism degrades to
# reactive behavior instead of diverging (pre-clamp versions pinned
# f_max on workloads whose online features left the training manifold).
TRUST_RADIUS = 0.15


def predict_targets(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """The deployed prediction: reactive digest + trust-clamped residual.

    Single definition shared by the online hook (``learn.mechanism``),
    offline evaluation (``learn.train``) and the figures, so the residual
    contract cannot drift between them."""
    x = jnp.asarray(x)
    react = x[..., list(REACT_COLS)]
    delta = apply_model(params, x)
    lim = TRUST_RADIUS * jnp.abs(react)
    return react + jnp.clip(delta, -lim, lim)


def fold_norm(params: Params, mu_x: np.ndarray, sd_x: np.ndarray,
              mu_y: np.ndarray, sd_y: np.ndarray) -> Params:
    """Fold feature/target standardization into the weights.

    Training computes ``y_n = f(x_n)`` with ``x_n = (x - mu_x) / sd_x``
    and ``y = y_n * sd_y + mu_y``; the returned parameters satisfy
    ``apply(folded, x) == apply(trained, x_n) * sd_y + mu_y`` exactly (up
    to float32 rounding), so the frozen hook consumes raw engine features
    with no normalization constants riding along."""
    mu_x, sd_x = (np.asarray(a, np.float32) for a in (mu_x, sd_x))
    mu_y, sd_y = (np.asarray(a, np.float32) for a in (mu_y, sd_y))
    p = {k: np.asarray(v, np.float32) for k, v in params.items()}
    if kind_of(p) == "linear":
        w = (p["w"] / sd_x[:, None]) * sd_y[None, :]
        b = p["b"] * sd_y + mu_y - mu_x @ w
        return {"w": w.astype(np.float32), "b": b.astype(np.float32)}
    w1 = p["w1"] / sd_x[:, None]
    b1 = p["b1"] - mu_x @ w1
    w2 = p["w2"] * sd_y[None, :]
    b2 = p["b2"] * sd_y + mu_y
    return {"w1": w1.astype(np.float32), "b1": b1.astype(np.float32),
            "w2": w2.astype(np.float32), "b2": b2.astype(np.float32)}
