"""Train the learned predictors on a factory dataset.

Reuses the seed's training stack exactly as the tentpole promises: the
``optim.adamw`` cosine-LR AdamW drives a jit-compiled pure
``(state, batch) -> (state, metrics)`` step in the ``train.train_step``
idiom (plain-dict state, so checkpoint/restore and multi-step wrappers
compose unchanged). Batches are drawn with the counter-based
``data.pipeline.stream_rng`` contract — step ``s`` of seed ``k`` is a
function of ``(k, s)`` alone, so runs are bit-reproducible and resumable.

Training operates in standardized feature/target space; :func:`fit`
returns FOLDED raw-space parameters (``models.fold_norm``) — the frozen
artifact a ``learn.mechanism`` spec deploys — plus the loss/accuracy
curves the figure and bench records report.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data import pipeline as PIPE
from repro.learn import dataset as LDS
from repro.learn import models as LM
from repro.optim import adamw


def norm_stats(a: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-column (mean, std) with a floor so constant columns (e.g. a
    never-missing hit feature) normalize to zero instead of exploding."""
    mu = a.mean(0).astype(np.float32)
    sd = np.maximum(a.std(0), 1e-6).astype(np.float32)
    return mu, sd


def make_train_step(kind: str, tc: TrainConfig, mu_y: np.ndarray,
                    sd_y: np.ndarray):
    """Jit-compiled MSE step (train_step idiom: pure function of a
    plain-dict state).

    The loss is computed through the DEPLOYED prediction — residual
    un-normalized and trust-clamped against the batch's raw react digest
    exactly as ``models.predict_targets`` will do at inference (then
    re-normalized so the objective is scale-balanced). Training the
    clamped function matters: with the clamp outside the loss the
    optimizer happily parks workloads on the clip boundary (zero
    training signal that the push is wasted); inside it, clipped rows
    contribute zero gradient to pushing further and capacity flows to
    corrections the trust region actually admits."""
    apply_fn = LM.APPLY[kind]
    mu_y, sd_y = jnp.asarray(mu_y), jnp.asarray(sd_y)

    def loss_fn(p, batch):
        delta = apply_fn(p, batch["x"]) * sd_y + mu_y
        lim = LM.TRUST_RADIUS * jnp.abs(batch["react"])
        pred = batch["react"] + jnp.clip(delta, -lim, lim)
        return jnp.mean(((pred - batch["y"]) / sd_y) ** 2)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        params, opt, om = adamw.update(grads, state["opt"],
                                       state["params"], tc)
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                {"loss": loss, **om})

    return jax.jit(step, donate_argnums=0), jax.jit(loss_fn)


def default_tc(kind: str, steps: int) -> TrainConfig:
    """Small-model defaults: shorter warmup, light decay; the cosine
    horizon is the actual step budget so the LR anneals to ~0."""
    return TrainConfig(lr=3e-2 if kind == "linear" else 1e-2,
                       warmup_steps=max(steps // 10, 1), total_steps=steps,
                       weight_decay=1e-3, grad_clip=1.0)


def fit(data: Dict[str, np.ndarray], meta: dict, *, kind: str = "linear",
        steps: int = 400, batch_size: int = 4096, seed: int = 0,
        hidden: int = 24, tc: Optional[TrainConfig] = None,
        noise_sigma: float = 1.0,
        noise_features: Tuple[str, ...] = ("pc_i0", "pc_sens", "f_prev",
                                           "pbar", "hit")
        ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Train ``kind`` on the dataset's train runs.

    Returns ``(params, curves)``: ``params`` are frozen RAW-space numpy
    weights (normalization folded in — deploy directly via
    ``learn.mechanism.make_learned_spec``); ``curves`` carries the
    per-step training loss, a deterministic jitter-free probe-loss curve
    (``curves["probe"]``, the smoke-testable training signal),
    normalized-space train/val MSE of the frozen model, and oracle
    frequency-choice agreement on both splits.

    Every feature except the react digest gets Gaussian jitter of
    ``noise_sigma`` normalized units at train time (``noise_features``):
    the react columns are the only pair whose offline reconstruction is
    exact, while the pc columns are a proxy (the real table lookups are
    not in the trace) and ``f_prev``/``pbar`` are policy-coupled.
    Without jitter the regression extracts precise workload-identity
    shortcuts from those columns — great offline, but the deployed
    closed loop sees different values and the misprediction feeds back
    on itself (pins f_max on held-out workloads). Jitter caps the
    precision the model can bank on, pushing weight onto the exactly
    reproduced react backbone; ``models.TRUST_RADIUS`` bounds the damage
    of whatever reliance remains."""
    train_mask, val_mask = LDS.split_masks(data)
    xt, yt_raw = data["x"][train_mask], data["y"][train_mask]
    react_raw = xt[:, list(LM.REACT_COLS)]
    # residual-head normalization stats: the net predicts the correction
    # over the reactive digest (models.predict_targets adds it back)
    mu_x, sd_x = norm_stats(xt)
    mu_y, sd_y = norm_stats(yt_raw - react_raw)
    xn = ((xt - mu_x) / sd_x).astype(np.float32)
    names = list(meta["feature_names"])
    noise_cols = np.asarray([names.index(f) for f in noise_features
                             if f in names], np.int64)

    params0 = (LM.init_linear(seed) if kind == "linear"
               else LM.init_mlp(seed, hidden))
    tc = tc or default_tc(kind, steps)
    if tc.total_steps != steps:
        tc = replace(tc, total_steps=steps)
    state = {"params": jax.tree.map(jnp.asarray, params0),
             "opt": adamw.init(params0),
             "step": jnp.zeros((), jnp.int32)}
    step_fn, loss_fn = make_train_step(kind, tc, mu_y, sd_y)

    n = xn.shape[0]
    bs = min(batch_size, n)
    Yd = jnp.asarray(yt_raw.astype(np.float32))
    Rd = jnp.asarray(react_raw.astype(np.float32))
    # deterministic jitter-free probe batch (counter `steps` is disjoint
    # from the per-step batch counters): the per-step minibatch loss is
    # dominated by jitter + sampling noise near the residual optimum, so
    # the smoke-testable "training improves the objective" signal is the
    # probe curve, not the raw step losses
    pidx = jnp.asarray(PIPE.stream_rng(seed, steps).integers(
        0, n, size=min(8192, n)))
    probe_batch = {"x": jnp.asarray(xn)[pidx], "react": Rd[pidx],
                   "y": Yd[pidx]}
    probe_every = max(1, steps // 10)
    losses, probe = [], [float(loss_fn(state["params"], probe_batch))]
    for s in range(steps):
        rng = PIPE.stream_rng(seed, s)
        idx = rng.integers(0, n, size=bs)
        xb = xn[idx]
        if noise_sigma > 0.0 and noise_cols.size:
            xb = xb.copy()
            xb[:, noise_cols] += rng.normal(
                0.0, noise_sigma, size=(bs, noise_cols.size)
            ).astype(np.float32)
        jdx = jnp.asarray(idx)
        state, m = step_fn(state, {"x": jnp.asarray(xb),
                                   "react": Rd[jdx], "y": Yd[jdx]})
        losses.append(float(m["loss"]))
        if (s + 1) % probe_every == 0 or s == steps - 1:
            probe.append(float(loss_fn(state["params"], probe_batch)))

    trained = {k: np.asarray(v) for k, v in state["params"].items()}
    params = LM.fold_norm(trained, mu_x, sd_x, mu_y, sd_y)

    pred = np.asarray(LM.predict_targets(params, jnp.asarray(data["x"])))
    norm = {"mu_x": mu_x, "sd_x": sd_x, "mu_y": mu_y, "sd_y": sd_y}
    curves = {"loss": losses, "probe": probe, "kind": kind,
              "steps": steps, "norm": norm}
    for split, mask in (("train", train_mask), ("val", val_mask)):
        if not mask.any():
            continue
        err_n = (pred[mask] - data["y"][mask]) / sd_y
        curves[f"{split}_mse"] = float(np.mean(err_n ** 2))
        curves[f"{split}_choice_acc"] = LDS.choice_accuracy(
            pred, data, meta, mask)
    return params, curves


def reactive_choice_baseline(data: Dict[str, np.ndarray], meta: dict,
                             mask: np.ndarray) -> float:
    """The reactive baseline's frequency-choice agreement with oracle on
    the same rows: select from the EMA fork-linear digest (feature
    columns react_i0/react_sens) — exactly what a reactive mechanism
    would lower through the objective. The acceptance bar for the learned
    heads."""
    names = list(meta["feature_names"])
    i, j = names.index("react_i0"), names.index("react_sens")
    pred = np.stack([data["x"][:, i], data["x"][:, j]], axis=-1)
    return LDS.choice_accuracy(pred, data, meta, mask)


def save_weights(path, params: Dict[str, np.ndarray], *,
                 extra_meta: Optional[dict] = None):
    """Frozen-weights artifact (canonical npz; see ``data.pipeline``)."""
    meta = {"kind": LM.kind_of(params),
            "feature_names": list(LM.FEATURE_NAMES),
            "target_names": list(LM.TARGET_NAMES)}
    meta.update(extra_meta or {})
    return PIPE.export_npz(path, params, meta)


def load_weights(path) -> Tuple[Dict[str, np.ndarray], dict]:
    return PIPE.load_npz(path)
