"""AdamW + cosine schedule + global-norm clipping, as pure pytree functions.

Optimizer moments live in the same sharding as their parameters (spec trees
are mapped 1:1), so FSDP sharding covers optimizer state for free.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def cosine_lr(tc: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    return tc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, opt: OptState, params, tc: TrainConfig
           ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    count = opt.count + 1
    lr = cosine_lr(tc, count)
    b1, b2 = tc.beta1, tc.beta2
    m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g, opt.m, grads)
    v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * g * g, opt.v, grads)
    mh = jax.tree.map(lambda mu: mu / (1 - b1 ** count), m)
    vh = jax.tree.map(lambda nu: nu / (1 - b2 ** count), v)

    def upd(p, mu, nu):
        step = lr * (mu / (jnp.sqrt(nu) + 1e-8) + tc.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mh, vh)
    return new_params, OptState(m, v, count), {"grad_norm": gnorm, "lr": lr}
