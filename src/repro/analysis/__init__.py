"""``repro.analysis`` — static analysis over the sweep substrate.

Two engines, both *advisory at import time and enforcing at dispatch/CI
time*:

* :mod:`repro.analysis.deps` — the **axis-liveness auditor**. Every
  registered :class:`~repro.core.mechanisms.MechanismSpec` hand-declares
  ``exec_axes``, the traced ``SimAxes`` fields its scan genuinely depends
  on; the sweep layer's grid deduplication broadcasts one scan across
  every grid point agreeing on those axes. An *under*-declared axis
  therefore silently broadcasts WRONG results — the worst failure mode a
  paper reproduction can have. The auditor abstract-evals the mechanism's
  fork/scan body (``jax.make_jaxpr`` at a tiny static shape; no compile),
  tags every ``SimAxes``/``PowerAxes`` leaf as a distinct jaxpr input and
  walks the closed jaxpr — recursing into ``scan``/``cond``/``while``/
  ``pjit`` sub-jaxprs and custom predict/update hooks — to derive the
  axes each output channel *actually* depends on, then compares against
  the declaration: under-declaration is a hard error
  (:class:`~repro.analysis.deps.AxisLivenessError`), over-declaration a
  warning naming the dead axis (missed dedup opportunity, visible in
  ``sweep.DISPATCH_ROWS``).

* :mod:`repro.analysis.lint` — the **trace-hazard linter**. An AST pass
  over the repo with rules for the failure modes this codebase has
  actually hit: host syncs on tracers, Python control flow on traced
  values, ``np.`` in traced code, non-donated scan carries, dict-ordering
  hazards in pytree construction, and unguarded module-level mutable
  state reached from dispatch threads (rules ``REPRO001``–``REPRO006``;
  see ``lint.RULES`` and the README rule table).

Wired in three places: ``mechanisms.register(verify_axes=...)`` audits
custom specs at registration, ``sweep.run_grid(dedup=True)`` refuses
under-declared specs before any deduped dispatch, and ``python -m
repro.analysis --check`` emits a machine-readable report for the CI
``analysis`` lane. The analysis never runs or perturbs compiled
executables — ``tests/data/grid_reference.npz`` stays byte-identical.
"""
from repro.analysis.deps import (AuditResult, AxisLivenessError,
                                 DeadAxisWarning, audit_registry,
                                 axis_liveness, require_dedup_sound,
                                 verify_spec_axes)
from repro.analysis.lint import Finding, RULES, lint_paths, lint_source

__all__ = [
    "AuditResult", "AxisLivenessError", "DeadAxisWarning",
    "audit_registry", "axis_liveness", "require_dedup_sound",
    "verify_spec_axes", "Finding", "RULES", "lint_paths", "lint_source",
]
