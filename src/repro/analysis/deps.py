"""Axis-liveness auditor: derive each mechanism's TRUE live ``SimAxes``
from the jaxpr and check the hand-declared ``exec_axes`` against it.

Why this exists
---------------
The sweep layer deduplicates grid points per mechanism by its declared
``MechanismSpec.exec_axes``: points agreeing on a spec's live axes share
one scan whose trace is broadcast to every member grid key
(``sweep._exec_classes``). That contract is only sound if the declaration
*over*-approximates the data flow the compiler actually sees:

* **under-declaration** — an axis the trace reads but the spec omits —
  makes the dedup broadcast results across grid points that genuinely
  differ: silently wrong numbers, the worst failure mode for a paper
  reproduction. The auditor turns this into a hard
  :class:`AxisLivenessError`.
* **over-declaration** — a declared axis the trace never touches — only
  costs dedup opportunity (extra scan rows, quantified by
  ``sweep.DISPATCH_ROWS``). The auditor emits a :class:`DeadAxisWarning`
  naming the dead axis.

How it works
------------
:func:`axis_liveness` abstract-evals the mechanism's *specialized* scan
(``simulate._scan_sim`` with the concrete spec — the semantics the grid
dedup relies on; the engine's dispatch contract makes the shared traced-id
family value-equal to it) via ``jax.make_jaxpr`` at a tiny static shape:
pure tracing, no XLA compile, a few hundred ms per spec. Every leaf of the
``SimAxes`` pytree — including the nested ``PowerAxes`` regime — is passed
as a distinct jaxpr input tagged with its axis field name, and the closed
jaxpr is walked bottom-up to propagate, per equation, which tagged inputs
each output can depend on:

* ``scan`` — fixpoint over the carry (the body matrix is applied until
  carry dependencies stabilize, so state threaded across epochs — e.g.
  the PC table carrying ``table_ema`` into later predictions — is
  captured);
* ``while`` — carry fixpoint plus the cond predicate's dependencies
  folded into every output (iteration count is data);
* ``cond`` — union over branches plus the predicate;
* ``pjit`` / ``closed_call`` / ``custom_jvp``/``custom_vjp`` / any other
  higher-order primitive carrying exactly one sub-jaxpr of matching arity
  — composed through precisely;
* anything else — conservative: every output depends on every input.
  (Conservativeness can only create FALSE under-declarations, never hide
  a real one; a spec hitting such a false positive documents it in
  ``MechanismSpec.liveness_waiver``.)

Custom ``predict``/``update`` hooks trace into the jaxpr like any other
code, so a hook that smuggles in an undeclared axis (say a blend weight
read from ``ax.table_ema``) is caught even though the spec's constructor
— which only knows the engine-imposed ``_REQUIRED_AXES`` list — cannot
see it.

Results are cached per ``(spec, static shape)`` (:func:`axis_liveness` is
``lru_cache``'d; specs are frozen/hashable and hook functions compare by
identity), so the registration-time check, the ``run_grid`` dispatch
guard and the CI report all share one trace per spec per process.

Since ``use_pallas`` became a grid engine mode, the registration-time
check (:func:`verify_spec_axes` with ``static_cfg=None``) audits the
specialized scan under BOTH engines: :data:`TINY_CONFIG` (jnp body) and
:data:`TINY_CONFIG_V2` (the fused v2 body, traced through the
direct-eval interpret engine so the jaxpr walk sees its real data flow).
An axis the v2 body reads but the spec omits is rejected exactly like a
jnp-body under-declaration.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.4.16 re-exports the stable jaxpr types here
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, Literal

from repro import kernels as KER
from repro.core import mechanisms as MECH
from repro.core import simulate as SIM
from repro.core import workloads as WL
from repro.core.mechanisms import MechanismSpec
from repro.core.simulate import SimConfig

# The audit point: the smallest static shape the engine accepts. Liveness
# is a property of the trace *structure*, not of array extents, so a
# 2-CU/2-WF/2-epoch scan over a 4-block program sees exactly the same
# data-flow graph as a production shape — at ~100x less tracing work.
TINY_CONFIG = SimConfig(n_cu=2, n_wf=2, n_epochs=2, entries=8,
                        offset_blocks=1)

# The same audit point under the fused-kernel grid engine: a v2-capable
# spec's specialized scan routes through ``kernels.epoch_fused``'s body
# instead of the jnp scan body, and the declared-axes contract must hold
# for THAT trace too (the grid dedup broadcasts it identically under
# ``use_pallas="v2"``). The default :func:`verify_spec_axes` call — the
# registration-time check — audits both configs and dedups via
# ``AuditResult`` equality, so a spec whose v2 trace happens to fall
# back to the jnp body (``v2_capable=False``, or no interpret engine)
# pays nothing extra.
TINY_CONFIG_V2 = dataclasses.replace(TINY_CONFIG, use_pallas="v2")


@functools.lru_cache(maxsize=1)
def _tiny_program() -> WL.Program:
    return WL._finalize("audit",
                        np.linspace(40.0, 80.0, 4),
                        np.linspace(20.0, 40.0, 4),
                        np.linspace(0.1, 0.5, 4))


class AxisLivenessError(ValueError):
    """A mechanism's trace depends on an axis its spec does not declare:
    deduplicated grid dispatch would broadcast wrong results."""


class DeadAxisWarning(UserWarning):
    """A declared exec axis the trace never reads: correct but wasteful
    (the grid dedup keeps equivalence classes apart for nothing)."""


# ---------------------------------------------------------------------------
# jaxpr dependency walk
# ---------------------------------------------------------------------------
#
# ``_matrix(jaxpr)`` returns, for every output variable of ``jaxpr``, the
# frozenset of *input positions* it (transitively) depends on. Sub-jaxprs
# are analyzed once and composed (memoized by object identity within one
# walk), so a scan body is walked a single time no matter how many
# fixpoint iterations the carry needs.

_Deps = FrozenSet[int]


def _apply(m: _Deps, ind: List[_Deps]) -> _Deps:
    return frozenset().union(*(ind[i] for i in m)) if m else frozenset()


def _sub_closed(params: dict) -> List[ClosedJaxpr]:
    """The sub-jaxprs an equation carries in its params (pjit's ``jaxpr``,
    custom_jvp's ``call_jaxpr``, remat's open ``jaxpr``, ...)."""
    subs = []
    for v in params.values():
        if isinstance(v, ClosedJaxpr):
            subs.append(v)
        elif isinstance(v, Jaxpr):
            subs.append(ClosedJaxpr(v, []))
    return subs


def _matrix(jaxpr: Jaxpr, memo: dict) -> List[_Deps]:
    key = id(jaxpr)
    if key in memo:
        return memo[key]
    env: Dict[object, _Deps] = {}
    for i, v in enumerate(jaxpr.invars):
        env[v] = frozenset((i,))
    for v in jaxpr.constvars:
        env[v] = frozenset()

    def read(a) -> _Deps:
        return frozenset() if isinstance(a, Literal) \
            else env.get(a, frozenset())

    for eqn in jaxpr.eqns:
        ind = [read(v) for v in eqn.invars]
        for v, d in zip(eqn.outvars, _eqn_deps(eqn, ind, memo)):
            env[v] = d
    res = [read(v) for v in jaxpr.outvars]
    memo[key] = res
    return res


def _scan_deps(eqn, ind: List[_Deps], memo: dict) -> List[_Deps]:
    """carry-out/ys deps of ``lax.scan``: fixpoint over the carry (state
    threaded across iterations accumulates dependencies until stable)."""
    p = eqn.params
    mat = _matrix(p["jaxpr"].jaxpr, memo)
    nc, ncar = p["num_consts"], p["num_carry"]
    consts, carry, xs = ind[:nc], list(ind[nc:nc + ncar]), ind[nc + ncar:]
    while True:
        body_out = [_apply(m, consts + carry + xs) for m in mat]
        new = [carry[i] | body_out[i] for i in range(ncar)]
        if new == carry:
            break
        carry = new
    return carry + body_out[ncar:]


def _cond_deps(eqn, ind: List[_Deps], memo: dict) -> List[_Deps]:
    """union over branches; the predicate taints every output."""
    pred, ops = ind[0], ind[1:]
    outs: Optional[List[_Deps]] = None
    for br in eqn.params["branches"]:
        o = [_apply(m, ops) for m in _matrix(br.jaxpr, memo)]
        outs = o if outs is None else [a | b for a, b in zip(outs, o)]
    return [pred | o for o in outs]


def _while_deps(eqn, ind: List[_Deps], memo: dict) -> List[_Deps]:
    """carry fixpoint over the body; the cond predicate (which decides the
    iteration count, and therefore every value) taints every output."""
    p = eqn.params
    cnc, bnc = p["cond_nconsts"], p["body_nconsts"]
    cmat = _matrix(p["cond_jaxpr"].jaxpr, memo)
    bmat = _matrix(p["body_jaxpr"].jaxpr, memo)
    cconsts, bconsts = ind[:cnc], ind[cnc:cnc + bnc]
    carry = list(ind[cnc + bnc:])
    while True:
        out = [_apply(m, bconsts + carry) for m in bmat]
        new = [carry[i] | out[i] for i in range(len(carry))]
        if new == carry:
            break
        carry = new
    pd = _apply(cmat[0], cconsts + carry)
    return [c | pd for c in carry]


def _eqn_deps(eqn, ind: List[_Deps], memo: dict) -> List[_Deps]:
    name = eqn.primitive.name
    if name == "scan":
        return _scan_deps(eqn, ind, memo)
    if name == "cond":
        return _cond_deps(eqn, ind, memo)
    if name == "while":
        return _while_deps(eqn, ind, memo)
    subs = _sub_closed(eqn.params)
    if len(subs) == 1 and len(subs[0].jaxpr.invars) == len(ind):
        # pjit / closed_call / custom_jvp / custom_vjp / remat: compose
        # through the sub-jaxpr precisely (inputs map positionally)
        return [_apply(m, ind) for m in _matrix(subs[0].jaxpr, memo)]
    # unknown structure: conservative — every output taints on every input
    # (can only create false liveness, never hide real liveness)
    u = frozenset().union(*ind) if ind else frozenset()
    return [u] * len(eqn.outvars)


# ---------------------------------------------------------------------------
# The audit
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AuditResult:
    """Derived-vs-declared liveness for one mechanism."""
    name: str
    declared: Tuple[str, ...]                    # spec.exec_axes
    derived: Tuple[str, ...]                     # union over outputs
    per_output: Tuple[Tuple[str, Tuple[str, ...]], ...]  # channel -> axes
    waiver: Optional[str] = None                 # spec.liveness_waiver

    @property
    def under_declared(self) -> Tuple[str, ...]:
        """Axes the trace reads but the spec omits (dedup-UNSOUND)."""
        return tuple(a for a in self.derived if a not in self.declared)

    @property
    def over_declared(self) -> Tuple[str, ...]:
        """Declared axes the trace never reads (dedup opportunity lost)."""
        return tuple(a for a in self.declared if a not in self.derived)

    @property
    def exact(self) -> bool:
        return self.declared == self.derived

    @property
    def sound(self) -> bool:
        """Safe for deduplicated grid dispatch."""
        return not self.under_declared or self.waiver is not None


def _leaf_axes(ax: SIM.SimAxes) -> List[str]:
    """Axis field name of every flattened SimAxes leaf, in flatten order
    (the nested PowerAxes regime contributes one tag — ``power`` — for
    each of its scalar leaves)."""
    names: List[str] = []
    for f, v in zip(ax._fields, ax):
        names += [f] * len(jax.tree_util.tree_leaves(v))
    return names


@functools.lru_cache(maxsize=256)
def axis_liveness(mech: Union[str, MechanismSpec],
                  static_cfg: Optional[SimConfig] = None) -> AuditResult:
    """Derive the axes each output channel of ``mech``'s scan genuinely
    depends on, by abstract evaluation at a tiny static shape (no
    compile). Cached per ``(spec, static)``.

    The audited object is the mechanism's *specialized* trace
    (``_scan_sim`` with the concrete spec): that is the semantics the
    grid dedup broadcasts, and — unlike the shared traced-id family,
    where every estimator is computed and ``jnp.where``-selected, making
    all axes appear live — it contains exactly the mechanism's own math.
    """
    spec = MECH.resolve(mech)
    cfg = TINY_CONFIG if static_cfg is None else static_cfg
    st = cfg.static_part()
    ax = cfg.axes()
    leaves, treedef = jax.tree_util.tree_flatten(ax)
    leaf_names = _leaf_axes(ax)
    prog = _tiny_program()

    def traced(*ax_leaves):
        axx = jax.tree_util.tree_unflatten(treedef, list(ax_leaves))
        return SIM._scan_sim(prog, jnp.int32(prog.n_blocks), jnp.int32(0),
                             st, axx, spec)

    closed, out_shape = jax.make_jaxpr(traced, return_shape=True)(*leaves)
    mat = _matrix(closed.jaxpr, {})
    keys = sorted(out_shape)  # dict pytrees flatten in sorted-key order
    assert len(mat) == len(keys), (len(mat), keys)
    per_out = {k: frozenset(leaf_names[i] for i in m)
               for k, m in zip(keys, mat)}
    derived = frozenset().union(*per_out.values()) if per_out else frozenset()

    def order(s):  # canonical SimAxes field order, like exec_axes
        return tuple(a for a in MECH.SIM_AXES_FIELDS if a in s)

    return AuditResult(
        name=spec.name, declared=spec.exec_axes, derived=order(derived),
        per_output=tuple((k, order(v)) for k, v in sorted(per_out.items())),
        waiver=spec.liveness_waiver)


def _enforce_audit(res: AuditResult, *, warn_over: bool = True) -> None:
    """Apply the declaration contract to one :class:`AuditResult`: raise
    :class:`AxisLivenessError` on unwaived under-declaration, warn
    :class:`DeadAxisWarning` on over-declaration (when ``warn_over``)."""
    under, over = res.under_declared, res.over_declared
    if under and res.waiver is None:
        culprits = [f"  {ch}: depends on {missing}" for ch, axes in
                    res.per_output
                    for missing in [tuple(a for a in axes if a in under)]
                    if missing]
        raise AxisLivenessError(
            f"mechanism {res.name!r} UNDER-declares exec_axes: its trace "
            f"depends on {under} but exec_axes={res.declared} omits "
            "them. Deduplicated grid dispatch (run_grid(dedup=True)) "
            "would broadcast one scan across grid points that differ on "
            "these axes — silently wrong results. Per-channel liveness:\n"
            + "\n".join(culprits) +
            f"\nFix: add {under} to the spec's exec_axes (costing only "
            "dedup opportunity if the auditor over-approximated), or — "
            "ONLY for a documented false positive of the conservative "
            "jaxpr walk — set liveness_waiver explaining why.")
    if under and res.waiver is not None:
        warnings.warn(
            f"mechanism {res.name!r} under-declares {under} under waiver: "
            f"{res.waiver}", DeadAxisWarning, stacklevel=3)
    if over and warn_over:
        warnings.warn(
            f"mechanism {res.name!r} over-declares exec_axes: {over} "
            f"is dead in its trace (declared {res.declared}, derived "
            f"{res.derived}). Correct but wasteful — grid points that "
            "differ only on a dead axis each get their own scan "
            "(DISPATCH_ROWS shows the extra rows). Drop the axis from "
            "exec_axes to let the dedup collapse them.",
            DeadAxisWarning, stacklevel=3)


def verify_spec_axes(mech: Union[str, MechanismSpec],
                     static_cfg: Optional[SimConfig] = None) -> AuditResult:
    """Audit ``mech`` and enforce the declaration contract: raise
    :class:`AxisLivenessError` on under-declaration (unless the spec
    carries a documented ``liveness_waiver``), warn
    :class:`DeadAxisWarning` on over-declaration naming the dead axes.

    At the default audit point (``static_cfg=None``) the spec is audited
    under BOTH engine modes — the jnp scan body (:data:`TINY_CONFIG`) and
    the fused-kernel v2 body (:data:`TINY_CONFIG_V2`) — since the grid
    dedup broadcasts whichever body ``SimStatic.use_pallas`` selects. The
    v2 pass enforces under-declaration only (the fused body computes some
    shared context either way, so a dead-axis warning there would be
    noise) and is skipped when it traces identically to the jnp pass or
    when no direct-eval interpret engine is available (a compiled
    ``pallas_call`` is opaque to the jaxpr walk and would taint every
    output with every axis)."""
    res = axis_liveness(mech, static_cfg)
    _enforce_audit(res)
    if static_cfg is None and KER._resolve_interpret(None):
        res2 = axis_liveness(mech, TINY_CONFIG_V2)
        if res2 != res:
            _enforce_audit(res2, warn_over=False)
    return res


def require_dedup_sound(mech: Union[str, MechanismSpec]) -> None:
    """Dispatch-time guard for ``run_grid(dedup=True)``: raise
    :class:`AxisLivenessError` if ``mech``'s trace reads an undeclared
    axis. Warning-free (over-declaration is flagged at registration/CI,
    not per dispatch) and cached, so the hot path pays one tiny trace per
    spec per process."""
    res = axis_liveness(mech)
    if not res.sound:
        verify_spec_axes(mech)  # raises with the full diagnostic


def audit_registry(static_cfg: Optional[SimConfig] = None
                   ) -> List[AuditResult]:
    """Audit every registered mechanism (the CI report entry point)."""
    return [axis_liveness(s, static_cfg) for s in MECH.specs()]
