"""Machine-readable analysis report: one JSON document combining the
axis-liveness audit of every registered mechanism with the trace-hazard
lint of the source tree. Consumed by the CI ``analysis`` lane
(``python -m repro.analysis --check``) and by humans via the CLI's text
rendering.

Report schema (stable; CI greps it)::

    {
      "schema": 1,
      "liveness": {
        "results": [
          {"name": "...", "declared": [...], "derived": [...],
           "status": "exact" | "over" | "under" | "waived",
           "under": [...], "over": [...], "waiver": null | "...",
           "per_output": {"channel": [...axes...], ...}},
          ...
        ],
        "unsound": ["<names of under-declared, unwaived specs>"]
      },
      "lint": {
        "findings": [
          {"rule": "REPRO00x", "path": "...", "line": N, "col": N,
           "msg": "...", "context": "...", "waived": bool}, ...
        ],
        "counts": {"REPRO00x": N, ...},
        "violations": N          # un-waived findings
      },
      "ok": bool                 # no unsound specs AND no violations
    }
"""
from __future__ import annotations

import json
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis import deps, lint

# Paths linted by default, relative to the repo root (the directory
# holding ``src/``). Generated/vendored trees would be excluded here.
DEFAULT_LINT_PATHS = ("src/repro",)


def _audit_row(res: deps.AuditResult) -> Dict:
    if res.under_declared:
        status = "waived" if res.waiver is not None else "under"
    elif res.over_declared:
        status = "over"
    else:
        status = "exact"
    return {
        "name": res.name,
        "declared": list(res.declared),
        "derived": list(res.derived),
        "status": status,
        "under": list(res.under_declared),
        "over": list(res.over_declared),
        "waiver": res.waiver,
        "per_output": {ch: list(axes) for ch, axes in res.per_output},
    }


def _find_repo_root(start: Optional[Path] = None) -> Path:
    """Walk up from this file to the directory containing ``src/``."""
    cur = (start or Path(__file__)).resolve()
    for parent in [cur] + list(cur.parents):
        if (parent / "src" / "repro").is_dir():
            return parent
    return Path.cwd()


def build_report(lint_paths: Optional[Sequence[str]] = None,
                 skip_liveness: bool = False,
                 skip_lint: bool = False) -> Dict:
    """Run both engines and assemble the report dict."""
    report: Dict = {"schema": 1}

    if not skip_liveness:
        with warnings.catch_warnings():
            # over-declarations are *reported*, not printed, here
            warnings.simplefilter("ignore", deps.DeadAxisWarning)
            results = deps.audit_registry()
        rows = [_audit_row(r) for r in results]
        report["liveness"] = {
            "results": rows,
            "unsound": [r["name"] for r in rows if r["status"] == "under"],
        }

    if not skip_lint:
        root = _find_repo_root()
        paths = [root / p for p in (lint_paths or DEFAULT_LINT_PATHS)]
        findings = lint.lint_paths([p for p in paths if p.exists()])
        counts: Dict[str, int] = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        report["lint"] = {
            "findings": [vars(f).copy() for f in findings],
            "counts": dict(sorted(counts.items())),
            "violations": len(lint.violations(findings)),
        }

    unsound = report.get("liveness", {}).get("unsound", [])
    nviol = report.get("lint", {}).get("violations", 0)
    report["ok"] = not unsound and nviol == 0
    return report


def render_text(report: Dict) -> str:
    """Human rendering of :func:`build_report`'s output."""
    lines: List[str] = []
    live = report.get("liveness")
    if live is not None:
        lines.append("axis-liveness audit "
                     f"({len(live['results'])} mechanisms):")
        width = max((len(r["name"]) for r in live["results"]), default=4)
        for r in live["results"]:
            mark = {"exact": "✓ exact", "over": "! over ",
                    "under": "✗ UNDER", "waived": "~ waive"}[r["status"]]
            detail = ""
            if r["under"]:
                detail = f"  undeclared={r['under']}"
            elif r["over"]:
                detail = f"  dead={r['over']}"
            lines.append(f"  {mark}  {r['name']:<{width}}  "
                         f"declared={r['declared']}{detail}")
        if live["unsound"]:
            lines.append(f"  UNSOUND (dedup would broadcast wrong "
                         f"results): {live['unsound']}")
    lnt = report.get("lint")
    if lnt is not None:
        lines.append(f"trace-hazard lint: {len(lnt['findings'])} findings "
                     f"({lnt['violations']} un-waived)")
        for f in lnt["findings"]:
            w = " (waived)" if f["waived"] else ""
            lines.append(f"  {f['path']}:{f['line']}: {f['rule']}{w} "
                         f"{f['msg']}")
    lines.append("OK" if report["ok"] else "FAIL")
    return "\n".join(lines)


def to_json(report: Dict) -> str:
    return json.dumps(report, indent=2, sort_keys=True)
