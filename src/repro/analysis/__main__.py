"""CLI: ``python -m repro.analysis [--check] [--json] [paths...]``

Runs the axis-liveness audit over every registered mechanism and the
trace-hazard linter over ``src/repro`` (or explicit paths), printing a
human-readable report by default or the stable JSON document with
``--json``. With ``--check`` the exit status is 1 unless the report is
clean: no under-declared, unwaived mechanism and no un-waived lint
finding — this is what the CI ``analysis`` lane runs.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import report as R


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="axis-liveness audit + trace-hazard lint")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any unsound spec or un-waived finding")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable JSON report")
    ap.add_argument("--no-liveness", action="store_true",
                    help="skip the (tracing) liveness audit")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint pass")
    args = ap.parse_args(argv)

    rep = R.build_report(lint_paths=args.paths or None,
                         skip_liveness=args.no_liveness,
                         skip_lint=args.no_lint)
    print(R.to_json(rep) if args.json else R.render_text(rep))
    return 0 if (rep["ok"] or not args.check) else 1


if __name__ == "__main__":
    sys.exit(main())
