"""Trace-hazard linter: an AST pass with repo-specific rules for the ways
JAX code in this codebase can go quietly wrong.

Rules
-----
``REPRO001`` **host sync on tracers** — ``float()``/``int()``/``bool()``/
    ``.item()``/``.tolist()``/``np.asarray()``/``np.array()`` applied to
    a value inside a traced context. Under ``jit`` these either fail at
    trace time or (worse, under ``io_callback``-style wrappers) silently
    synchronize the device per call. Conversions of shape/static values
    (``int(x.shape[0])``, ``len(...)``) are exempt.
``REPRO002`` **Python control flow on traced values** — ``if``/``while``/
    ``assert`` whose test calls into ``jnp``/``lax`` (e.g. ``if
    jnp.any(mask):``). Inside a trace this raises a
    ``TracerBoolConversionError`` at best; at worst the branch is taken
    on the *tracer's* truthiness during tracing and baked into the
    compiled graph. Use ``jnp.where``/``lax.cond``.
``REPRO003`` **``np.`` where ``jnp.`` is required** — a ``numpy``
    computation inside a traced context constant-folds the tracer's
    *abstract* value or raises; dtype constructors and scalar constants
    (``np.float32(...)``, ``np.pi``) are exempt, as is ``np.asarray``
    (reported as REPRO001, the sharper diagnosis).
``REPRO004`` **non-donated scan carry** — a ``jax.jit``-decorated
    function that runs ``lax.scan`` but declares no ``donate_argnums``:
    the caller's carry buffers stay pinned for the whole dispatch (the
    sweep layer's grid executables donate; see ``sweep._grid_exec``).
    Advisory — a carry built in-trace has nothing to donate; waive it.
``REPRO005`` **dict-ordering hazard in pytree construction** — a dict
    built with non-literal keys (comprehension, ``dict(zip(...))``)
    inside a traced context. Dict pytrees flatten in *sorted-key* order;
    two construction sites whose key sets differ — or race — produce
    structurally different pytrees and silent cache misses or crossed
    channels.
``REPRO006`` **unguarded module-level mutable state** — a module-level
    ``dict``/``list``/``set``/``Counter``/``defaultdict`` mutated
    somewhere in the module without a surrounding ``with <lock>:`` block.
    The DVFS service mutates sweep-layer counters from dispatch threads;
    unlocked read-modify-write increments drop updates.

Traced-context detection is deliberately syntactic and conservative: a
function is *traced* if it (a) is decorated with ``jit`` (directly or via
``functools.partial(jax.jit, ...)``), (b) is passed to a JAX transform or
control-flow combinator (``jit``/``vmap``/``pmap``/``grad``/``scan``/
``cond``/``while_loop``/``fori_loop``/``switch``/``shard_map``/
``pallas_call``/``checkpoint``/``remat``/``custom_jvp``/``custom_vjp``),
(c) is lexically nested inside a traced function, or (d) is a same-module
function called from a traced function (propagated to a fixpoint). This
catches the engine's real traced surface (scan bodies, hook functions,
jitted dispatchers) without pretending to be a type checker.

Waivers
-------
Intentional violations carry an inline waiver naming the rule and a
reason::

    x = float(dbg_val)  # repro: waive[REPRO001] interpret-mode host read

on the flagged line or the line directly above. A file-level waiver
(``# repro: waive-file[REPRO004] <reason>``, anywhere in the file's first
comment block) silences a rule for the whole file. Waived findings stay
in the machine-readable report with ``waived: true`` so CI can count —
but not fail on — them.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

RULES: Dict[str, str] = {
    "REPRO001": "host sync on tracer (float()/.item()/np.asarray in "
                "traced code)",
    "REPRO002": "Python if/while/assert on a traced value",
    "REPRO003": "np. computation where jnp. is required in traced code",
    "REPRO004": "jitted scan without donate_argnums (carry stays pinned)",
    "REPRO005": "dict with non-literal keys in traced pytree "
                "construction (sorted-key flatten order hazard)",
    "REPRO006": "module-level mutable state mutated without a lock",
}

# JAX transform / control-flow entry points whose function-valued
# arguments trace (attribute name is enough: jax.jit, lax.scan, ...)
_TRANSFORMS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "jacfwd", "jacrev",
    "scan", "cond", "while_loop", "fori_loop", "switch", "associative_scan",
    "shard_map", "pallas_call", "checkpoint", "remat", "custom_jvp",
    "custom_vjp", "named_call", "make_jaxpr", "eval_shape",
}

_HOST_SYNC_CALLS = {"float", "int", "bool", "complex"}
_HOST_SYNC_METHODS = {"item", "tolist", "__array__"}
_NP_HOST_FUNCS = {"asarray", "array"}
# numpy names that are static/constant-producing, fine inside a trace
_NP_STATIC_OK = {
    "float32", "float64", "float16", "int32", "int64", "int8", "int16",
    "uint8", "uint32", "uint64", "bool_", "dtype", "pi", "e", "inf", "nan",
    "newaxis", "ndim", "shape", "isscalar", "issubdtype", "finfo", "iinfo",
}
_MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "subtract",
}
_MUTABLE_CTORS = {"dict", "list", "set", "Counter", "OrderedDict",
                  "defaultdict", "deque"}

_WAIVE_RE = re.compile(r"#\s*repro:\s*waive\[([A-Z0-9, ]+)\]")
_WAIVE_FILE_RE = re.compile(r"#\s*repro:\s*waive-file\[([A-Z0-9, ]+)\]")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    msg: str
    context: str = ""          # enclosing function, if any
    waived: bool = False

    def format(self) -> str:
        w = " (waived)" if self.waived else ""
        ctx = f" [{self.context}]" if self.context else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}{w} " \
               f"{self.msg}{ctx}"


def _call_name(node: ast.AST) -> Optional[str]:
    """Terminal name of a call target: ``jax.jit`` -> ``jit``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root_name(node: ast.AST) -> Optional[str]:
    """Leftmost name of an attribute chain: ``np.linalg.norm`` -> ``np``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _is_jit_decorator(dec: ast.AST) -> bool:
    """``@jax.jit``, ``@jit``, ``@functools.partial(jax.jit, ...)``."""
    if _call_name(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        if _call_name(dec.func) == "jit":
            return True
        if _call_name(dec.func) == "partial" and dec.args \
                and _call_name(dec.args[0]) == "jit":
            return True
    return False


def _decorator_donates(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        return any(kw.arg in ("donate_argnums", "donate_argnames")
                   for kw in dec.keywords)
    return False


_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


class _Parents(ast.NodeVisitor):
    """Annotate every node with its parent (ast has no uplinks)."""

    def __init__(self, tree: ast.AST):
        self.parent: Dict[ast.AST, Optional[ast.AST]] = {tree: None}
        self.visit(tree)

    def generic_visit(self, node: ast.AST):
        for child in ast.iter_child_nodes(node):
            self.parent[child] = node
        super().generic_visit(node)


def _enclosing_funcs(node: ast.AST, parents: Dict) -> List[ast.AST]:
    out = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, _FUNC_NODES):
            out.append(cur)
        cur = parents.get(cur)
    return out


def _traced_functions(tree: ast.Module, parents: Dict) -> Set[ast.AST]:
    """The set of function nodes considered traced (see module doc)."""
    funcs = [n for n in ast.walk(tree) if isinstance(n, _FUNC_NODES)]
    by_name: Dict[str, List[ast.AST]] = {}
    for f in funcs:
        if not isinstance(f, ast.Lambda):
            by_name.setdefault(f.name, []).append(f)

    traced: Set[ast.AST] = set()
    for f in funcs:
        if not isinstance(f, ast.Lambda) and \
                any(_is_jit_decorator(d) for d in f.decorator_list):
            traced.add(f)
    # functions (by name or inline) passed to a transform
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call) or \
                _call_name(call.func) not in _TRANSFORMS:
            continue
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Lambda):
                traced.add(arg)
            elif isinstance(arg, ast.Name):
                traced.update(by_name.get(arg.id, ()))

    # fixpoint: lexical nesting + same-module calls from traced bodies
    while True:
        grew = False
        for f in funcs:
            if f in traced:
                continue
            if any(e in traced for e in _enclosing_funcs(f, parents)):
                traced.add(f)
                grew = True
        for f in list(traced):
            for call in ast.walk(f):
                if isinstance(call, ast.Call) and \
                        isinstance(call.func, ast.Name):
                    for g in by_name.get(call.func.id, ()):
                        if g not in traced:
                            traced.add(g)
                            grew = True
        if not grew:
            return traced


def _expr_touches_traced_math(node: ast.AST) -> bool:
    """Does this expression call into jnp/lax (a traced-value producer)?"""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Call, ast.Attribute)):
            root = _root_name(sub.func if isinstance(sub, ast.Call) else sub)
            if root in ("jnp", "lax"):
                return True
    return False


def _under_lock(node: ast.AST, parents: Dict) -> bool:
    """Is ``node`` inside a ``with <something lock-like>:`` block?"""
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, ast.With):
            for item in cur.items:
                for sub in ast.walk(item.context_expr):
                    if isinstance(sub, (ast.Name, ast.Attribute)):
                        name = sub.attr if isinstance(sub, ast.Attribute) \
                            else sub.id
                        if "lock" in name.lower():
                            return True
        cur = parents.get(cur)
    return False


def _fn_label(node: ast.AST, parents: Dict) -> str:
    encl = _enclosing_funcs(node, parents)
    names = [f.name for f in reversed(encl) if not isinstance(f, ast.Lambda)]
    return ".".join(names)


@dataclass
class _FileLint:
    path: str
    source: str
    findings: List[Finding] = field(default_factory=list)

    def __post_init__(self):
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=self.path)
        self.parents = _Parents(self.tree).parent
        self.traced = _traced_functions(self.tree, self.parents)
        self.file_waivers: Set[str] = set()
        for ln in self.lines:
            m = _WAIVE_FILE_RE.search(ln)
            if m:
                self.file_waivers.update(
                    r.strip() for r in m.group(1).split(","))

    # -- waiver lookup ------------------------------------------------------

    def _line_waivers(self, line: int) -> Set[str]:
        out: Set[str] = set()
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                m = _WAIVE_RE.search(self.lines[ln - 1])
                if m:
                    out.update(r.strip() for r in m.group(1).split(","))
        return out

    def emit(self, rule: str, node: ast.AST, msg: str):
        waived = rule in self.file_waivers or \
            rule in self._line_waivers(node.lineno)
        self.findings.append(Finding(
            rule, self.path, node.lineno, node.col_offset, msg,
            context=_fn_label(node, self.parents), waived=waived))

    def in_traced(self, node: ast.AST) -> bool:
        return any(f in self.traced for f in
                   _enclosing_funcs(node, self.parents))

    # -- the pass -----------------------------------------------------------

    def run(self) -> List[Finding]:
        self._module_state_rule()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._call_rules(node)
            elif isinstance(node, (ast.If, ast.While, ast.Assert)):
                self._branch_rule(node)
            elif isinstance(node, (ast.DictComp, ast.Dict)):
                self._dict_rule(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._donate_rule(node)
        return self.findings

    def _call_rules(self, node: ast.Call):
        traced = self.in_traced(node)
        fn = node.func
        # REPRO001: host conversions on (potential) tracers
        if traced and isinstance(fn, ast.Name) \
                and fn.id in _HOST_SYNC_CALLS and node.args:
            arg = node.args[0]
            src = ast.unparse(arg)
            if not (isinstance(arg, ast.Constant) or ".shape" in src
                    or "len(" in src or "ndim" in src):
                self.emit("REPRO001", node,
                          f"{fn.id}({src}) forces a host sync if the "
                          "operand is traced; compute in jnp or hoist "
                          "out of the jitted path")
        if traced and isinstance(fn, ast.Attribute) \
                and fn.attr in _HOST_SYNC_METHODS:
            self.emit("REPRO001", node,
                      f".{fn.attr}() on a traced value synchronizes the "
                      "host; keep device values device-side")
        if isinstance(fn, ast.Attribute) and _root_name(fn) == "np":
            if traced and fn.attr in _NP_HOST_FUNCS:
                self.emit("REPRO001", node,
                          f"np.{fn.attr}() materializes on host inside "
                          "traced code; use jnp.asarray (stays abstract)")
            # REPRO003: numpy compute in traced code
            elif traced and fn.attr not in _NP_STATIC_OK \
                    and fn.attr not in _NP_HOST_FUNCS:
                self.emit("REPRO003", node,
                          f"np.{fn.attr} in traced code operates on "
                          "concrete arrays only — use jnp."
                          f"{fn.attr} so the op traces")
        # REPRO005 (dict(zip(...)) form)
        if self.in_traced(node) and isinstance(fn, ast.Name) \
                and fn.id == "dict" and node.args \
                and isinstance(node.args[0], ast.Call) \
                and _call_name(node.args[0].func) == "zip":
            self.emit("REPRO005", node,
                      "dict(zip(...)) in traced code builds a pytree "
                      "whose key set is data-dependent; dict pytrees "
                      "flatten in sorted-key order — use a literal key "
                      "set so the treedef is static")

    def _branch_rule(self, node):
        if not self.in_traced(node):
            return
        test = node.test
        if _expr_touches_traced_math(test):
            kind = type(node).__name__.lower()
            self.emit("REPRO002", node,
                      f"Python {kind} on a jnp/lax expression "
                      f"({ast.unparse(test)[:60]}): inside a trace this "
                      "is TracerBoolConversionError at best — use "
                      "jnp.where / lax.cond")

    def _dict_rule(self, node):
        if not self.in_traced(node):
            return
        if isinstance(node, ast.DictComp):
            self.emit("REPRO005", node,
                      "dict comprehension in traced code: the key set "
                      "(and so the pytree treedef, which flattens "
                      "sorted) is runtime data — prefer literal keys")
        elif isinstance(node, ast.Dict):
            bad = [k for k in node.keys
                   if k is not None and not isinstance(k, ast.Constant)]
            if bad:
                self.emit("REPRO005", node,
                          f"dict with non-literal key "
                          f"({ast.unparse(bad[0])}) in traced pytree "
                          "construction: flatten order is sorted-by-key "
                          "and must be static")

    def _donate_rule(self, node):
        jit_decs = [d for d in node.decorator_list if _is_jit_decorator(d)]
        if not jit_decs or any(_decorator_donates(d) for d in jit_decs):
            return
        has_scan = any(
            isinstance(c, ast.Call) and _call_name(c.func) == "scan"
            for c in ast.walk(node))
        if has_scan:
            self.emit("REPRO004", node,
                      f"jitted {node.name}() runs lax.scan without "
                      "donate_argnums: a caller-built carry stays "
                      "pinned for the whole dispatch (waive if the "
                      "carry is built in-trace)")

    def _module_state_rule(self):
        # module-level mutable containers...
        mutables: Dict[str, ast.AST] = {}
        for stmt in self.tree.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
                value = stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            else:
                continue
            is_mut = isinstance(value, (ast.Dict, ast.List, ast.Set,
                                        ast.DictComp, ast.ListComp,
                                        ast.SetComp)) or (
                isinstance(value, ast.Call)
                and _call_name(value.func) in _MUTABLE_CTORS)
            if not is_mut:
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    mutables[t.id] = stmt
        if not mutables:
            return
        # ... mutated anywhere in the module without a lock
        flagged: Set[str] = set()
        for node in ast.walk(self.tree):
            name = None
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATING_METHODS \
                    and isinstance(node.func.value, ast.Name):
                name = node.func.value.id
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in tgts:
                    if isinstance(t, ast.Subscript) \
                            and isinstance(t.value, ast.Name):
                        name = t.value.id
            if name in mutables and name not in flagged \
                    and not _under_lock(node, self.parents):
                flagged.add(name)
                self.emit("REPRO006", node,
                          f"module-level mutable {name!r} mutated "
                          "without a lock: dispatch threads (DVFSService) "
                          "make unlocked read-modify-write lose updates "
                          "— guard with a module Lock or waive if "
                          "provably single-threaded")


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string; returns findings (waived ones included,
    marked)."""
    return _FileLint(path, source).run()


def lint_paths(paths: Sequence[Path],
               exclude: Iterable[str] = ()) -> List[Finding]:
    """Lint ``.py`` files under the given files/directories."""
    files: List[Path] = []
    for p in map(Path, paths):
        files += sorted(p.rglob("*.py")) if p.is_dir() else [p]
    out: List[Finding] = []
    for f in files:
        if any(x in str(f) for x in exclude):
            continue
        out += lint_source(f.read_text(), str(f))
    return out


def violations(findings: Iterable[Finding]) -> List[Finding]:
    """The findings that should fail a check (un-waived)."""
    return [f for f in findings if not f.waived]
