"""Sharded checkpointing with atomic rename + fault-tolerant resume.

Layout: <dir>/step_<N>/shard_<host>.npz + MANIFEST.json (written last —
a checkpoint without a manifest is incomplete and ignored on restore).
Flat dotted-path keys keep the npz schema stable across pytree refactors.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16: store as f32 (lossless)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(state, ckpt_dir: str, step: int, host_id: int = 0,
         keep: int = 3) -> str:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    tmp = tempfile.NamedTemporaryFile(dir=d, delete=False, suffix=".tmp")
    np.savez(tmp, **flat)
    tmp.close()
    os.replace(tmp.name, d / f"shard_{host_id:05d}.npz")
    # manifest written LAST = commit point
    manifest = {"step": step, "n_leaves": len(flat), "host": host_id}
    mtmp = d / f".manifest_{host_id}.tmp"
    mtmp.write_text(json.dumps(manifest))
    os.replace(mtmp, d / "MANIFEST.json")
    _gc(ckpt_dir, keep)
    return str(d)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(Path(ckpt_dir).glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    best = None
    for d in sorted(Path(ckpt_dir).glob("step_*")):
        if (d / "MANIFEST.json").exists():  # complete checkpoints only
            best = int(d.name.split("_")[1])
    return best


def restore(state_template, ckpt_dir: str, step: Optional[int] = None,
            host_id: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of ``state_template``. Returns (state, step).
    Raises FileNotFoundError if no complete checkpoint exists."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    with np.load(d / f"shard_{host_id:05d}.npz") as z:
        flat = {k: z[k] for k in z.files}
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    new_leaves = []
    for path, leaf in leaves_t:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return (jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_template), new_leaves), step)
