"""Train / serve step builders.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``:
  * microbatch gradient accumulation via ``lax.scan`` over a leading
    microbatch axis (batch arrives as (M, B/M, ...)),
  * optional gradient compression before the cross-replica reduce
    ('bf16' cast or 'int8_ef' error-feedback quantization),
  * AdamW update.

State is a plain dict so spec trees mirror it trivially.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import decode_step, loss_fn, prefill
from repro.optim import adamw

TrainState = Dict[str, Any]


def init_state(cfg: ModelConfig, tc: TrainConfig, key: jax.Array) -> TrainState:
    from repro.models.model import init_params
    params = init_params(cfg, key)
    state: TrainState = {"params": params, "opt": adamw.init(params),
                         "step": jnp.zeros((), jnp.int32)}
    if tc.grad_compression == "int8_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return state


def _compress_bf16(g):
    return jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32), g)


def _compress_int8_ef(g, ef):
    """Error-feedback int8: quantize (g + ef) per-tensor, carry residual."""
    def q(x, e):
        x = x.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        qx = jnp.round(x / scale).astype(jnp.int8)
        deq = qx.astype(jnp.float32) * scale
        return deq, x - deq
    flat, tree = jax.tree.flatten(g)
    eflat = jax.tree.leaves(ef)
    out = [q(x, e) for x, e in zip(flat, eflat)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params = state["params"]

        def one_mb(carry, mb):
            gsum, lsum = carry
            (loss, _metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, mb), has_aux=True)(params)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = lax.scan(one_mb, (zeros, jnp.float32(0.0)), batch)
        n_mb = jax.tree.leaves(batch)[0].shape[0]
        grads = jax.tree.map(lambda g: g / n_mb, gsum)
        loss = lsum / n_mb

        new_state = dict(state)
        if tc.grad_compression == "bf16":
            grads = _compress_bf16(grads)
        elif tc.grad_compression == "int8_ef":
            grads, ef = _compress_int8_ef(grads, state["ef"])
            new_state["ef"] = ef

        new_params, new_opt, om = adamw.update(grads, state["opt"], params, tc)
        new_state.update(params=new_params, opt=new_opt, step=state["step"] + 1)
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)
    return serve_step
