"""Elastic scaling + straggler mitigation policies.

On a real cluster these hooks are driven by the job controller's health
signals; here they are deterministic pure functions so the behavior is unit
testable and the dry-run can exercise every re-mesh transition.

 * pod loss      -> degrade (2,16,16) -> (16,16); batch respecified over the
                    surviving DP axes, params resharded (specs re-derived on
                    the new mesh — same rule set, so only axis sizes change).
 * straggler     -> per-step deadline policy: steps whose measured duration
                    exceeds ``k`` x trailing-median are flagged; after
                    ``patience`` consecutive flags the launcher re-meshes
                    (drop the slow pod) instead of waiting forever.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List


@dataclass
class StragglerDetector:
    k: float = 2.0
    patience: int = 3
    window: int = 32
    _hist: List[float] = field(default_factory=list)
    _strikes: int = 0

    def observe(self, step_seconds: float) -> str:
        """Returns 'ok' | 'slow' | 'remesh'."""
        hist = self._hist
        hist.append(step_seconds)
        if len(hist) > self.window:
            hist.pop(0)
        if len(hist) < 8:
            return "ok"
        med = sorted(hist)[len(hist) // 2]
        if step_seconds > self.k * med:
            self._strikes += 1
            return "remesh" if self._strikes >= self.patience else "slow"
        self._strikes = 0
        return "ok"


def plan_remesh(n_pods_alive: int, multi_pod: bool):
    """Decide the mesh for the surviving fleet. Returns kwargs for
    repro.launch.mesh.make_production_mesh / make_mesh."""
    if not multi_pod or n_pods_alive >= 2:
        return {"multi_pod": multi_pod}
    return {"multi_pod": False}  # collapse to single-pod mesh


def rescale_batch(global_batch: int, n_pods_alive: int, n_pods_total: int = 2,
                  keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-device work grows) or
    scale it with the surviving fleet (keep step time, change optimizer
    schedule accordingly)."""
    if keep_global:
        return global_batch
    return max(global_batch * n_pods_alive // n_pods_total, 1)
