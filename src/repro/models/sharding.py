"""Sharding rules: PartitionSpec trees for params, optimizer state, batches
and caches on the production mesh.

Strategy (DESIGN.md §6):
  * batch            → ('pod','data')   (pure DP over pods, DP within pod)
  * TP / EP          → 'model'
  * FSDP             → parameter d_model-ish dims sharded over 'data'
                       (scan-over-layers all-gathers one layer per step)
  * any dim that does not divide its mesh axis is replicated (documented
    per-arch in DESIGN.md §Arch-applicability).

Specs are derived *structurally*: we walk the abstract param tree and assign
a spec from the leaf's path name + shape, so new params pick up rules by name.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh_axes: Dict[str, int], name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh_axes.get(n, 1) for n in name]))
    return mesh_axes.get(name, 1)


def _div(dim: int, mesh_axes: Dict[str, int], name):
    """Return the axis name if dim divides that mesh axis size, else None."""
    return name if (name is not None and dim % max(_axis_size(mesh_axes, name), 1) == 0
                    and _axis_size(mesh_axes, name) > 1) else None


def param_specs(cfg: ModelConfig, abstract_params, mesh_axes: Dict[str, int]):
    """Spec tree mirroring the params pytree.

    The model-parallel dimension may be a single axis ('model') or a factored
    ('expert','tp') pair (Perf log #B2): EP over 'expert' for the expert dim,
    TP over 'tp' inside each expert, and dense/attention dims over the full
    product.
    """
    # FSDP spans every data-parallel axis: ('pod','data') on the multi-pod
    # mesh halves per-device param+optimizer bytes vs 'data'-only (Perf #3).
    data = ("pod", "data") if "pod" in mesh_axes else "data"
    factored = "expert" in mesh_axes and "tp" in mesh_axes
    model = ("expert", "tp") if factored else "model"
    ep_axis = "expert" if factored else "model"
    tp_axis = "tp" if factored else "model"

    def rule(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        shp = leaf.shape
        stacked = names[0] == "layers"  # leading L axis
        pre = (None,) if stacked else ()
        s = shp[1:] if stacked else shp

        if name in ("embed", "lm_head"):
            V, D = shp
            v = _div(V, mesh_axes, model)
            if v:
                return P(v, _div(D, mesh_axes, data))
            return P(None, _div(D, mesh_axes, model))
        if name in ("wq", "wk", "wv") and len(s) == 3:  # attn (D,H,hd)
            return P(*pre, _div(s[0], mesh_axes, data), _div(s[1], mesh_axes, model), None)
        if name == "wo" and len(s) == 3:  # (H,hd,D)
            return P(*pre, _div(s[0], mesh_axes, model), None, _div(s[2], mesh_axes, data))
        if name in ("w1", "w3") and len(s) == 3:  # moe (E,D,F)
            e = _div(s[0], mesh_axes, ep_axis)
            if e and factored:  # EP x TP hybrid
                return P(*pre, e, _div(s[1], mesh_axes, data),
                         _div(s[2], mesh_axes, tp_axis))
            if e:
                return P(*pre, e, _div(s[1], mesh_axes, data), None)
            return P(*pre, None, _div(s[1], mesh_axes, data), _div(s[2], mesh_axes, tp_axis))
        if name == "w2" and len(s) == 3:  # moe (E,F,D)
            e = _div(s[0], mesh_axes, ep_axis)
            if e and factored:
                return P(*pre, e, _div(s[1], mesh_axes, tp_axis),
                         _div(s[2], mesh_axes, data))
            if e:
                return P(*pre, e, None, _div(s[2], mesh_axes, data))
            return P(*pre, None, _div(s[1], mesh_axes, tp_axis), _div(s[2], mesh_axes, data))
        if name in ("w1", "w3", "sw1", "sw3", "ck", "w_in"):  # (D,F)
            return P(*pre, _div(s[0], mesh_axes, data), _div(s[1], mesh_axes, model))
        if name in ("w2", "sw2", "cv", "w_out"):  # (F,D)
            return P(*pre, _div(s[0], mesh_axes, model), _div(s[1], mesh_axes, data))
        if name in ("wr", "wk", "wv", "wg", "cr"):  # rwkv (D,D)
            # time-mix projections: keep the OUTPUT dim unsharded — the head
            # reshape (40 heads % 16 != 0) would force a reshard all-gather
            # per layer otherwise (Perf log #2); FSDP on the input dim only.
            return P(*pre, _div(s[0], mesh_axes, data), None)
        if name == "router":  # (D,E)
            return P(*pre, _div(s[0], mesh_axes, data), None)
        if name in ("wa",):  # (D,lora)
            return P(*pre, _div(s[0], mesh_axes, data), None)
        if name in ("wb",):  # (lora,D)
            return P(*pre, None, _div(s[1], mesh_axes, model))
        if name in ("conv_k",):  # (K,Di)
            return P(*pre, None, _div(s[1], mesh_axes, model))
        if name in ("w_dt", "w_b", "w_c"):  # (Di, small)
            return P(*pre, _div(s[0], mesh_axes, model), None)
        # vectors / norms / scalars: replicate
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def batch_axes(mesh_axes: Dict[str, int], batch_size: int):
    dp = ("pod", "data") if "pod" in mesh_axes else ("data",)
    if batch_size % _axis_size(mesh_axes, dp) == 0 and batch_size > 1:
        return dp
    if batch_size % mesh_axes.get("data", 1) == 0 and batch_size > 1:
        return ("data",)
    return None


def batch_specs(cfg: ModelConfig, abstract_batch, mesh_axes: Dict[str, int],
                microbatched: bool):
    def rule(path, leaf):
        b_dim = 1 if microbatched else 0
        if leaf.ndim <= b_dim:
            return P()
        dp = batch_axes(mesh_axes, leaf.shape[b_dim])
        spec = [None] * leaf.ndim
        spec[b_dim] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def cache_specs(cfg: ModelConfig, abstract_cache, mesh_axes: Dict[str, int]):
    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        if leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        # all cache tensors are (L, B, ...)
        dp = batch_axes(mesh_axes, leaf.shape[1])
        spec = [None, dp] + [None] * (leaf.ndim - 2)
        if name in ("k", "v") and leaf.ndim == 5:
            spec[3] = _div(leaf.shape[3], mesh_axes, "model")  # Hkv
        if name == "conv" and leaf.ndim == 4:
            spec[3] = _div(leaf.shape[3], mesh_axes, "model")  # Di channels
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def state_specs(cfg: ModelConfig, abstract_state, mesh_axes: Dict[str, int]):
    """Specs for the full TrainState (params + adam moments + step [+ ef])."""
    pspecs = param_specs(cfg, abstract_state["params"], mesh_axes)
    out: Dict[str, Any] = {"params": pspecs, "step": P()}
    out["opt"] = type(abstract_state["opt"])(m=pspecs, v=pspecs, count=P())
    if "ef" in abstract_state:
        out["ef"] = pspecs
    return out


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
