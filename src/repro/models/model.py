"""Model zoo: init / forward / loss / decode for all assigned architectures.

Families:
  dense / audio / vlm : decoder transformer (GQA + RoPE + SwiGLU), optional
                        modality prefix (vlm) — audio consumes EnCodec ids.
  moe                 : dense attention + MoE FFN (shared + routed top-k).
  hybrid (hymba)      : parallel attention (SWA) + mamba heads per layer.
  ssm (rwkv6)         : attention-free time-mix/channel-mix.

Params are a nested dict; per-layer params are stacked on a leading L axis
and consumed with ``lax.scan`` (O(1) HLO size at 126 layers) wrapped in
``jax.checkpoint`` (remat).
"""
# repro: waive-file[REPRO003] np.sqrt here only touches static config ints
# (init-scale constants folded at trace time), never traced arrays
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import act_sharding as AS
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _dense_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig, dt):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd), dt),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), dt),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), dt),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model), dt,
                          scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }


def _init_mlp(key, cfg: ModelConfig, dt):
    ks = jax.random.split(key, 3)
    return {
        "w1": _dense_init(ks[0], (cfg.d_model, cfg.d_ff), dt),
        "w3": _dense_init(ks[1], (cfg.d_model, cfg.d_ff), dt),
        "w2": _dense_init(ks[2], (cfg.d_ff, cfg.d_model), dt,
                          scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }


def _init_moe(key, cfg: ModelConfig, dt):
    e = cfg.moe
    ks = jax.random.split(key, 7)
    p = {
        "router": _dense_init(ks[0], (cfg.d_model, e.num_experts), jnp.float32),
        "w1": _dense_init(ks[1], (e.num_experts, cfg.d_model, e.expert_d_ff), dt),
        "w3": _dense_init(ks[2], (e.num_experts, cfg.d_model, e.expert_d_ff), dt),
        "w2": _dense_init(ks[3], (e.num_experts, e.expert_d_ff, cfg.d_model), dt,
                          scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }
    if e.num_shared:
        fs = e.num_shared * (e.shared_d_ff or e.expert_d_ff)
        p["sw1"] = _dense_init(ks[4], (cfg.d_model, fs), dt)
        p["sw3"] = _dense_init(ks[5], (cfg.d_model, fs), dt)
        p["sw2"] = _dense_init(ks[6], (fs, cfg.d_model), dt,
                               scale=0.02 / np.sqrt(2 * cfg.n_layers))
    return p


def _init_mamba(key, cfg: ModelConfig, dt):
    d = cfg.d_model
    di = d * (cfg.ssm.expand if cfg.ssm else 1)
    hd = cfg.resolved_head_dim
    H = di // hd
    n = cfg.ssm.state_size
    ks = jax.random.split(key, 6)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), dt),
        "conv_k": _dense_init(ks[1], (cfg.ssm.conv_width, di), dt, scale=0.5),
        "w_dt": _dense_init(ks[2], (di, H), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "w_b": _dense_init(ks[3], (di, n), dt),
        "w_c": _dense_init(ks[4], (di, n), dt),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "w_out": _dense_init(ks[5], (di, d), dt,
                             scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }


def _init_rwkv_layer(key, cfg: ModelConfig, dt):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H = d // hd
    lora = 64
    ks = jax.random.split(key, 10)
    mu = lambda: jnp.full((d,), 0.5, jnp.float32)
    return {
        "tm": {
            "mu_r": mu(), "mu_k": mu(), "mu_v": mu(), "mu_w": mu(), "mu_g": mu(),
            "wr": _dense_init(ks[0], (d, d), dt),
            "wk": _dense_init(ks[1], (d, d), dt),
            "wv": _dense_init(ks[2], (d, d), dt),
            "wg": _dense_init(ks[3], (d, d), dt),
            "wo": _dense_init(ks[4], (d, d), dt, scale=0.02 / np.sqrt(2 * cfg.n_layers)),
            "w0": jnp.full((d,), -1.0, jnp.float32),
            "wa": _dense_init(ks[5], (d, lora), jnp.float32),
            "wb": _dense_init(ks[6], (lora, d), jnp.float32),
            "u": jnp.zeros((d,), jnp.float32),
            "ln_w": jnp.ones((d,), jnp.float32),
            "ln_b": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mu_ck": mu(), "mu_cr": mu(),
            "ck": _dense_init(ks[7], (d, cfg.d_ff), dt),
            "cv": _dense_init(ks[8], (cfg.d_ff, d), dt, scale=0.02 / np.sqrt(2 * cfg.n_layers)),
            "cr": _dense_init(ks[9], (d, d), dt),
        },
        "norm1": jnp.zeros((d,), jnp.float32),
        "norm2": jnp.zeros((d,), jnp.float32),
    }


def _init_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    d = cfg.d_model
    if cfg.family == "ssm":
        return _init_rwkv_layer(key, cfg, dt)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": jnp.zeros((d,), jnp.float32),
                 "norm2": jnp.zeros((d,), jnp.float32)}
    p["attn"] = _init_attn(k1, cfg, dt)
    if cfg.family == "hybrid":
        p["mamba"] = _init_mamba(k3, cfg, dt)
        p["norm_a"] = jnp.zeros((d,), jnp.float32)
        p["norm_s"] = jnp.zeros((d,), jnp.float32)
    p["mlp" if cfg.moe is None else "moe"] = (
        _init_mlp(k2, cfg, dt) if cfg.moe is None else _init_moe(k2, cfg, dt))
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    p = {
        "embed": _dense_init(k_emb, (cfg.vocab, cfg.d_model), dt),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(k_head, (cfg.vocab, cfg.d_model), dt)
    return p


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _attn_block(x, p, cfg: ModelConfig, positions, prefix_len=0):
    hd = cfg.resolved_head_dim
    q = AS.shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), head_dim=2)
    k = AS.shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), head_dim=2)
    v = AS.shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), head_dim=2)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if cfg.attn_kind == "swa" else 0
    o = L.attention(q, k, v, causal=True, window=window, prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _layer_fwd(x, lp, cfg: ModelConfig, positions, prefix_len):
    """One transformer block. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        B = x.shape[0]
        d = cfg.d_model
        hd = cfg.resolved_head_dim
        H = d // hd
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        x0 = jnp.zeros((B, 1, d), x.dtype)
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        y, _, _ = RWKV.time_mix_chunked(h, x0, S0, lp["tm"], H, hd)
        x = x + y
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        y, _ = RWKV.channel_mix(h, jnp.zeros((B, 1, d), x.dtype), lp["cm"])
        return x + y, aux

    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a = _attn_block(h, lp["attn"], cfg, positions, prefix_len)
        st = SSM.init_mamba_state(x.shape[0], cfg.d_model, cfg.resolved_head_dim,
                                  cfg.ssm.state_size, cfg.ssm.conv_width, x.dtype)
        s, _ = SSM.mamba_head(h, lp["mamba"], st, cfg.resolved_head_dim,
                              cfg.ssm.state_size)
        y = 0.5 * (L.rms_norm(a, lp["norm_a"], cfg.norm_eps)
                   + L.rms_norm(s, lp["norm_s"], cfg.norm_eps))
    else:
        y = _attn_block(h, lp["attn"], cfg, positions, prefix_len)
    x = x + y
    h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = MOE.moe_layer(h, lp["moe"], cfg.moe)
    else:
        y = L.swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
    return x + y, aux


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, int]:
    """Returns (x (B,S,D), prefix_len). For vlm: patch embeds prepended."""
    tok_emb = AS.shard_batch(params["embed"][batch["tokens"]])
    if cfg.frontend == "vision":
        pe = batch["patch_embeds"].astype(tok_emb.dtype)
        return AS.shard_batch(jnp.concatenate([pe, tok_emb], axis=1)), cfg.n_patches
    return tok_emb, 0


def backbone(params: Params, cfg: ModelConfig, x: jax.Array, prefix_len: int
             ) -> Tuple[jax.Array, jax.Array]:
    """Run all layers. Returns (hidden (B,S,D), total_aux)."""
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]

    body = functools.partial(_layer_fwd, cfg=cfg, positions=positions,
                             prefix_len=prefix_len)

    def scan_body(carry, lp):
        x, aux = carry
        x = AS.shard_batch(x)
        x, a = body(x, lp)
        return (AS.shard_batch(x), aux + a), None

    if cfg.remat != "none":
        scan_body = jax.checkpoint(scan_body, policy=_remat_policy(cfg),
                                   prevent_cse=False)
    (x, aux), _ = lax.scan(scan_body, (x, jnp.float32(0.0)), params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, prefix_len = embed_inputs(params, cfg, batch)
    h, aux = backbone(params, cfg, x, prefix_len)
    emb_out = params.get("lm_head", params["embed"])
    labels, mask = batch["labels"], batch["mask"].astype(jnp.float32)
    ce = L.chunked_ce_loss(h, emb_out, labels, mask)
    moe_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    loss = ce + moe_w * aux / max(cfg.n_layers, 1)
    return loss, {"ce": ce, "aux": aux}


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Serve prefill: returns last-position logits (B,V)."""
    x, prefix_len = embed_inputs(params, cfg, batch)
    h, _ = backbone(params, cfg, x, prefix_len)
    emb_out = params.get("lm_head", params["embed"])
    return jnp.einsum("bd,vd->bv", h[:, -1].astype(jnp.float32),
                      emb_out.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Decode (single token, stateful cache)
# ---------------------------------------------------------------------------


def cache_capacity(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attn_kind == "swa":
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, fill: int = 0) -> Dict[str, Any]:
    """Abstract-friendly cache. ``fill`` = number of tokens already in cache."""
    dt = _dtype(cfg)
    Lr = cfg.n_layers
    hd = cfg.resolved_head_dim
    c: Dict[str, Any] = {"pos": jnp.full((), fill, jnp.int32)}
    if cfg.family == "ssm":
        d = cfg.d_model
        H = d // hd
        c["S"] = jnp.zeros((Lr, batch, H, hd, hd), jnp.float32)
        c["x_tm"] = jnp.zeros((Lr, batch, 1, d), dt)
        c["x_cm"] = jnp.zeros((Lr, batch, 1, d), dt)
        return c
    W = cache_capacity(cfg, max_len)
    c["k"] = jnp.zeros((Lr, batch, W, cfg.n_kv_heads, hd), dt)
    c["v"] = jnp.zeros((Lr, batch, W, cfg.n_kv_heads, hd), dt)
    if cfg.family == "hybrid":
        di = cfg.d_model * cfg.ssm.expand
        H = di // hd
        c["ssm_h"] = jnp.zeros((Lr, batch, H, hd, cfg.ssm.state_size), jnp.float32)
        c["conv"] = jnp.zeros((Lr, batch, cfg.ssm.conv_width - 1, di), dt)
    return c


def _decode_attn(x, p, cfg: ModelConfig, kc, vc, pos):
    """x (B,1,D); kc/vc (B,W,Hkv,hd). Returns (y, kc, vc)."""
    W = kc.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    posb = jnp.full((x.shape[0], 1), pos)
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    slot = pos % W if cfg.attn_kind == "swa" else jnp.minimum(pos, W - 1)
    kc = lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
    vc = lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
    valid = jnp.arange(W)[None, :] <= pos  # ring: all valid once pos >= W
    if cfg.attn_kind == "swa":
        valid = valid | (jnp.full((1, W), pos) >= W)
    valid = jnp.broadcast_to(valid, (x.shape[0], W))
    o = L.decode_attention(q, kc, vc, valid)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), kc, vc


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens (B,) int32 → (logits (B,V), new cache)."""
    x = AS.shard_batch(params["embed"][tokens][:, None, :])  # (B,1,D)
    pos = cache["pos"]
    hd = cfg.resolved_head_dim

    if cfg.family == "ssm":
        d = cfg.d_model
        H = d // hd

        def body(x, xs):
            lp, S0, xtm, xcm = xs
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
            y, S1, xtm1 = RWKV.time_mix(h, xtm, S0, lp["tm"], H, hd)
            x = x + y
            h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
            y, xcm1 = RWKV.channel_mix(h, xcm, lp["cm"])
            return x + y, (S1, xtm1.astype(xtm.dtype), xcm1.astype(xcm.dtype))

        x, (S, xtm, xcm) = lax.scan(body, x, (params["layers"], cache["S"],
                                              cache["x_tm"], cache["x_cm"]))
        new_cache = {"pos": pos + 1, "S": S, "x_tm": xtm, "x_cm": xcm}
    else:
        def body(x, xs):
            if cfg.family == "hybrid":
                lp, kc, vc, hst, cst = xs
            else:
                lp, kc, vc = xs
            h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
            if cfg.family == "hybrid":
                a, kc, vc = _decode_attn(h, lp["attn"], cfg, kc, vc, pos)
                s, st = SSM.mamba_head(h, lp["mamba"], {"h": hst, "conv": cst},
                                       hd, cfg.ssm.state_size)
                y = 0.5 * (L.rms_norm(a, lp["norm_a"], cfg.norm_eps)
                           + L.rms_norm(s, lp["norm_s"], cfg.norm_eps))
            else:
                y, kc, vc = _decode_attn(h, lp["attn"], cfg, kc, vc, pos)
            x = x + y
            h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
            if cfg.moe is not None:
                y, _ = MOE.moe_layer(h, lp["moe"], cfg.moe)
            else:
                y = L.swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
            x = x + y
            if cfg.family == "hybrid":
                return x, (kc, vc, st["h"], st["conv"])
            return x, (kc, vc)

        if cfg.family == "hybrid":
            xs = (params["layers"], cache["k"], cache["v"], cache["ssm_h"], cache["conv"])
            x, (k, v, hs, cs) = lax.scan(body, x, xs)
            new_cache = {"pos": pos + 1, "k": k, "v": v, "ssm_h": hs, "conv": cs}
        else:
            xs = (params["layers"], cache["k"], cache["v"])
            x, (k, v) = lax.scan(body, x, xs)
            new_cache = {"pos": pos + 1, "k": k, "v": v}

    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)[:, 0]
    emb_out = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h.astype(jnp.float32), emb_out.astype(jnp.float32))
    return logits, new_cache
