"""Mamba-style selective SSM head (used by the hymba hybrid arch).

Mamba2-flavoured: per-head scalar decay A, data-dependent dt/B/C, depthwise
conv front-end. Reference scan is exact; used both for train (scan over seq)
and decode (single-step state update).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan(xh: jax.Array, dt: jax.Array, B_: jax.Array, C_: jax.Array,
             A: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Selective scan.
    xh (B,S,H,hd), dt (B,S,H), B_/C_ (B,S,N), A (H,) negative, h0 (B,H,hd,N).
    Returns y (B,S,H,hd), h_out."""

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,hd),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A[None])  # (B,H)
        dBx = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        h = h * decay[..., None, None] + dBx  # (B,H,hd,N)
        y = jnp.einsum("bhdn,bn->bhd", h, ct)
        return h, y

    sf = lambda t: t.swapaxes(0, 1)
    h_out, y = lax.scan(step, h0.astype(jnp.float32),
                        (sf(xh.astype(jnp.float32)), sf(dt.astype(jnp.float32)),
                         sf(B_.astype(jnp.float32)), sf(C_.astype(jnp.float32))))
    return y.swapaxes(0, 1), h_out


def depthwise_conv(x: jax.Array, kernel: jax.Array, carry: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv. x (B,S,Di), kernel (K,Di), carry (B,K-1,Di)."""
    K = kernel.shape[0]
    xp = jnp.concatenate([carry, x], axis=1)  # (B, S+K-1, Di)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(K))
    return out, xp[:, -(K - 1):]


def mamba_head(x: jax.Array, p: dict, state: dict, head_dim: int, n_state: int
               ) -> Tuple[jax.Array, dict]:
    """x (B,S,D) -> (y (B,S,D), new_state).
    state: {'h': (B,H,hd,N), 'conv': (B,K-1,Di)}."""
    B, S, D = x.shape
    xz = x @ p["w_in"]  # (B,S,2*Di)
    Di = xz.shape[-1] // 2
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_carry = depthwise_conv(xi, p["conv_k"], state["conv"])
    xi = jax.nn.silu(xi)
    H = Di // head_dim
    dt = jax.nn.softplus(xi.astype(jnp.float32) @ p["w_dt"] + p["dt_bias"])  # (B,S,H)
    B_ = xi @ p["w_b"]  # (B,S,N)
    C_ = xi @ p["w_c"]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    xh = xi.reshape(B, S, H, head_dim)
    y, h_out = ssm_scan(xh, dt, B_, C_, A, state["h"])
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, Di).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], {"h": h_out, "conv": conv_carry}


def init_mamba_state(batch: int, d_inner: int, head_dim: int, n_state: int,
                     conv_width: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, d_inner // head_dim, head_dim, n_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
    }
