"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Two sharding strategies (selected automatically by the config, see
DESIGN.md §Arch-applicability):
  * EP  — expert dim sharded over the 'model' mesh axis (granite-moe, 32e/16).
  * TPE — TP inside each expert's d_ff (qwen2-moe, 60e not divisible by 16).

Dispatch is sort-based (argsort by expert id + capacity slots), not one-hot
matmul, so routed FLOPs stay proportional to top_k rather than num_experts.

Perf note (§Perf hillclimb): dispatch is vmapped over the *batch* row dim —
flattening (B,S,D)->(B*S,D) merges the DP-sharded batch axis into an
unsharded token axis and GSPMD responds with full all-gathers of the
activations. Row-local dispatch keeps every buffer batch-sharded; the only
cross-device traffic left is the legitimate expert-parallel all-to-all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MoEConfig


def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """x (T,D), w_router (D,E) -> softmax probs (T,E) in fp32."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def topk_dispatch(probs: jax.Array, top_k: int, capacity: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort-based dispatch plan for T tokens.

    Returns (slot, weight, src_token, aux):
      slot (T*k,)  flat index into (E*C) expert buffers (clipped),
      weight (T*k,) normalized routing weight (0 where dropped),
      src_token (T*k,) source token index,
      aux: GShard load-balance loss.
    """
    T, E = probs.shape
    vals, ids = lax.top_k(probs, top_k)  # (T,k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    flat_e = ids.reshape(-1)  # (T*k,)
    flat_w = vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    src_token = order // top_k
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(T * top_k) - first
    keep = pos < capacity
    slot = sorted_e * capacity + jnp.minimum(pos, capacity - 1)
    weight = jnp.where(keep, flat_w[order], 0.0)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)
    return slot, weight, src_token, aux


def moe_layer(x: jax.Array, params: dict, cfg: MoEConfig,
              capacity_factor: float = 1.25, seq_chunk: int = 4096
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D). Returns (y, aux_loss). Scans over sequence chunks so the
    (B,E,C,D) buffers stay bounded for 32k-token sequences."""
    B, S, D = x.shape
    chunk = min(seq_chunk, S)
    if S % chunk:
        chunk = S
    n_chunks = S // chunk
    E, k = cfg.num_experts, cfg.top_k
    capacity = max(int(chunk * k * capacity_factor / E), 4)

    def row(xc):  # (chunk, D) — one batch row, stays on its DP shard
        probs = router_probs(xc, params["router"])
        slot, weight, src, aux = topk_dispatch(probs, k, capacity)
        buf = jnp.zeros((E * capacity, D), xc.dtype).at[slot].set(
            jnp.where(weight[:, None] > 0, xc[src], 0))
        return buf.reshape(E, capacity, D), (slot, weight, src), aux

    def combine_row(ye, plan, dtype):
        slot, weight, src = plan
        yc = jnp.zeros((chunk, D), dtype)
        return yc.at[src].add(ye.reshape(E * capacity, D)[slot]
                              * weight[:, None].astype(dtype))

    def body(aux_acc, xc):  # xc (B, chunk, D)
        buf, plan, aux = jax.vmap(row)(xc)           # (B,E,C,D)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w1"])) \
            * jnp.einsum("becd,edf->becf", buf, params["w3"])
        ye = jnp.einsum("becf,efd->becd", h, params["w2"])
        yc = jax.vmap(lambda y, p: combine_row(y, p, xc.dtype))(ye, plan)
        if cfg.num_shared:
            hs = jax.nn.silu(xc @ params["sw1"]) * (xc @ params["sw3"])
            yc = yc + hs @ params["sw2"]
        return aux_acc + aux.mean(), yc

    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)  # (nc,B,chunk,D)
    aux, y = lax.scan(body, jnp.float32(0.0), xc)
    return y.swapaxes(0, 1).reshape(B, S, D), aux / n_chunks
