"""Activation sharding constraints.

GSPMD loses the batch sharding of activations after the embedding gather
(vocab-sharded table indexed by batch-sharded ids propagates 'replicated'),
so we pin activations at layer boundaries. The batch axes are process-global
state set by the launcher (dryrun/train) right before tracing; model code
stays mesh-agnostic and this is a no-op outside a mesh context.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_BATCH_SIZE: int = 1
_MODEL_AXIS: Optional[str] = None
_MODEL_SIZE: int = 1


def set_activation_axes(batch_axes, model_axis=None,
                        batch_size: int = 1, model_size: int = 1) -> None:
    global _BATCH_AXES, _MODEL_AXIS, _BATCH_SIZE, _MODEL_SIZE
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _MODEL_AXIS = model_axis
    _BATCH_SIZE = max(batch_size, 1)
    _MODEL_SIZE = max(model_size, 1)


def clear_activation_axes() -> None:
    set_activation_axes(None, None)


def shard_batch(x: jax.Array, batch_dim: int = 0) -> jax.Array:
    """Constrain dim ``batch_dim`` to the data-parallel axes."""
    if _BATCH_AXES is None or x.shape[batch_dim] % _BATCH_SIZE:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = _BATCH_AXES
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_heads(x: jax.Array, head_dim: int, batch_dim: int = 0) -> jax.Array:
    """Batch on DP axes + head/channel dim on the model axis (if divisible)."""
    if _BATCH_AXES is None or x.shape[batch_dim] % _BATCH_SIZE:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = _BATCH_AXES
    if _MODEL_AXIS is not None and x.shape[head_dim] % _MODEL_SIZE == 0:
        spec[head_dim] = _MODEL_AXIS
    return jax.lax.with_sharding_constraint(x, P(*spec))
