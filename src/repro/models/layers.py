"""Shared model layers: RMSNorm, RoPE, SwiGLU, block-wise attention.

Pure-jnp reference path used everywhere (works on CPU and compiles for any
mesh); the Pallas flash-attention kernel in ``repro.kernels`` is a drop-in
for the TPU hot path (selected via ``attn_backend='pallas'``).

All attention here is *block-wise* (lax.scan over query blocks) so the
compiled memory footprint for 32k-token prefill stays bounded: scores are
materialized only per (q-block × kv) tile, never (S × S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd//2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (.., S, 1, hd//2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (block-wise, causal / sliding-window / prefix-LM)
# ---------------------------------------------------------------------------


def _mha_block(q, k, v, mask, scale):
    """q: (B,bq,H,hd)  k/v: (B,bk,Hkv,hd) with H = Hkv*rep. mask (bq,bk) or None."""
    B, bq, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, bq, Hkv, rep, hd)
    # bf16 operands + fp32 accumulation: halves score-operand HBM traffic
    # vs fp32 upcast while keeping softmax numerics in fp32 (Perf log #3).
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, bq, H, hd).astype(q.dtype)


def _causal_pair_attention(q, k, v, scale, q_block: int) -> jax.Array:
    """Exact causal attention scanning only the non-masked (i,j<=i) block
    pairs with online softmax — flash attention at jnp block granularity.

    vs the naive per-q-block full-S path this does S^2/2 + S*qb/2 work
    instead of S^2 per head (Perf log #D): ~1.9x fewer attention FLOPs and
    score-tile HBM traffic at 32k prefill.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    nb = S // q_block
    pi = jnp.asarray([i for i in range(nb) for j in range(i + 1)])
    pj = jnp.asarray([j for i in range(nb) for j in range(i + 1)])
    qb = q.reshape(B, nb, q_block, Hkv, rep, hd)
    kb = k.reshape(B, nb, q_block, Hkv, hd)
    vb = v.reshape(B, nb, q_block, Hkv, hd)
    tril = jnp.tril(jnp.ones((q_block, q_block), bool))

    m0 = jnp.full((B, nb, Hkv, rep, q_block), -1e30, jnp.float32)
    l0 = jnp.zeros((B, nb, Hkv, rep, q_block), jnp.float32)
    a0 = jnp.zeros((B, nb, Hkv, rep, q_block, hd), jnp.float32)

    def body(carry, ij):
        m, l, acc = carry
        i, j = ij
        qi = lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)  # (B,qb,Hkv,rep,hd)
        kj = lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)  # (B,qb,Hkv,hd)
        vj = lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        allowed = tril | (i != j)
        s = jnp.where(allowed[None, None, None], s, -1e30)
        m_i = lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        l_i = lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        a_i = lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(m_i, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(jnp.maximum(m_i - m_new, -80.0))
        l_new = l_i * alpha + p.sum(-1)
        a_new = a_i * alpha[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        m = lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        acc = lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        return (m, l, acc), None

    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (pi, pj))
    out = acc / jnp.maximum(l, 1e-20)[..., None]       # (B,nb,Hkv,rep,qb,hd)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    q_block: int = 1024,
) -> jax.Array:
    """Block-wise multi-head attention.

    q (B,S,H,hd), k/v (B,S,Hkv,hd). ``window>0`` → sliding-window attention
    (each query sees the previous ``window`` keys); ``prefix_len>0`` →
    prefix-LM (first ``prefix_len`` positions are mutually visible).
    """
    B, S, H, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    if S <= q_block:
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        mask = kj <= qi if causal else jnp.ones((S, S), bool)
        if window:
            mask = mask & (kj > qi - window)
        if prefix_len:
            mask = mask | (kj < prefix_len)
        return _mha_block(q, k, v, mask, scale)

    assert S % q_block == 0, (S, q_block)
    nb = S // q_block
    if causal and not window and not prefix_len:
        return _causal_pair_attention(q, k, v, scale, q_block)
    qb = q.reshape(B, nb, q_block, H, hd)

    if window and window <= 8192:
        # sliding window: each q block needs kv slice [start - window, start + q_block)
        span = q_block + window

        def body(_, inp):
            qblk, i = inp
            start = jnp.maximum(i * q_block - window, 0)
            ks = lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = lax.dynamic_slice_in_dim(v, start, span, axis=1)
            qpos = i * q_block + jnp.arange(q_block)[:, None]
            kpos = start + jnp.arange(span)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            if prefix_len:
                mask = mask | (kpos < prefix_len)
            return None, _mha_block(qblk, ks, vs, mask, scale)

        _, out = lax.scan(body, None, (qb.swapaxes(0, 1), jnp.arange(nb)))
    else:
        # causal over full prefix, one q block at a time
        def body(_, inp):
            qblk, i = inp
            qpos = i * q_block + jnp.arange(q_block)[:, None]
            kpos = jnp.arange(S)[None, :]
            mask = kpos <= qpos if causal else jnp.ones((q_block, S), bool)
            if window:
                mask = mask & (kpos > qpos - window)
            if prefix_len:
                mask = mask | (kpos < prefix_len)
            return None, _mha_block(qblk, k, v, mask, scale)

        _, out = lax.scan(body, None, (qb.swapaxes(0, 1), jnp.arange(nb)))
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_mask: jax.Array) -> jax.Array:
    """Single-token decode. q (B,1,H,hd), caches (B,W,Hkv,hd), valid (B,W) bool."""
    hd = q.shape[-1]
    scale = 1.0 / (hd ** 0.5)
    B, W = valid_mask.shape
    Hkv = k_cache.shape[2]
    H = q.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, hd)
    scores = jnp.einsum("bhrd,bkhd->bhrk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    scores = jnp.where(valid_mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrk,bkhd->bhrd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w1)) * jnp.einsum("bsd,df->bsf", x, w3)
    return jnp.einsum("bsf,fd->bsd", h, w2)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (bounds logits memory: V up to 257k)
# ---------------------------------------------------------------------------


def chunked_ce_loss(x: jax.Array, emb_out: jax.Array, labels: jax.Array,
                    mask: jax.Array, chunk: int = 512) -> jax.Array:
    """x (B,S,D) final hidden; emb_out (V,D); labels/mask (B,S).

    Computes softmax CE scanning over sequence chunks so the (tokens × V)
    logits tensor never materializes whole.
    """
    B, S, D = x.shape
    if S % chunk:
        chunk = S  # tiny smoke shapes
    nb = S // chunk
    xc = x.reshape(B, nb, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, nb, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nb, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xb, lb, mb = inp
        logits = jnp.einsum("bsd,vd->bsv", xb.astype(jnp.float32),
                            emb_out.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mb
        return (acc[0] + nll.sum(), acc[1] + mb.sum()), None

    (tot, cnt), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
