"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

Reference implementation scans token-by-token (exact recurrence). The
chunked formulation (matmul-friendly for the MXU) lives in
``repro.kernels.rwkv_chunk`` and is validated against this scan.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """shift-by-one along seq; x (B,S,D), x_prev (B,1,D) is the carry-in."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def time_mix_step(S, r, k, v, w, u):
    """One-token WKV update, per head.
    S (hd,hd); r,k,w,u (hd,); v (hd,). Returns (S', y (hd,))."""
    a = jnp.outer(k, v)  # (hd_k, hd_v)
    y = r @ (S + u[:, None] * a)
    S = w[:, None] * S + a
    return S, y


def time_mix(x: jax.Array, x_prev: jax.Array, S0: jax.Array, p: dict,
             n_heads: int, head_dim: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """RWKV6 time-mix. x (B,S,D); S0 (B,H,hd,hd). Returns y, S_out, x_last."""
    B, S, D = x.shape
    xs = token_shift(x, x_prev)
    xr = _mix(x, xs, p["mu_r"])
    xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"])
    xw = _mix(x, xs, p["mu_w"])
    xg = _mix(x, xs, p["mu_g"])
    r = (xr @ p["wr"]).astype(jnp.float32)
    k = (xk @ p["wk"]).astype(jnp.float32)
    v = (xv @ p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (lora): w in (0,1)
    wln = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wa"]) @ p["wb"]
    w = jnp.exp(-jnp.exp(wln.astype(jnp.float32)))  # (B,S,D)

    hs = (B, S, n_heads, head_dim)
    r, k, v, w = (t.reshape(hs) for t in (r, k, v, w))
    u = p["u"].reshape(n_heads, head_dim).astype(jnp.float32)

    def step(Sc, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        fn = jax.vmap(jax.vmap(time_mix_step, in_axes=(0, 0, 0, 0, 0, 0)),
                      in_axes=(0, 0, 0, 0, 0, None))
        Sc, y = fn(Sc, rt, kt, vt, wt, u)
        return Sc, y

    seq_first = lambda t: t.swapaxes(0, 1)  # (S,B,H,hd)
    S_out, y = lax.scan(step, S0.astype(jnp.float32),
                        tuple(map(seq_first, (r, k, v, w))))
    y = y.swapaxes(0, 1).reshape(B, S, D)  # (B,S,D)
    # per-head group norm
    yh = y.reshape(B, S, n_heads, head_dim)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * lax.rsqrt(var + 64e-5)
    y = (yh.reshape(B, S, D) * p["ln_w"] + p["ln_b"]).astype(x.dtype)
    y = (y * g).astype(x.dtype) @ p["wo"]
    return y, S_out, x[:, -1:]


def time_mix_chunked(x: jax.Array, x_prev: jax.Array, S0: jax.Array, p: dict,
                     n_heads: int, head_dim: int, chunk: int = 128
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-parallel WKV (same math as kernels/rwkv_chunk.py, pure jnp).

    Perf hillclimb for train/prefill: the exact token scan reads+writes the
    (B,H,hd,hd) state every token; the chunked form touches it once per
    ``chunk`` tokens and turns the inner work into MXU matmuls. Exact
    (validated vs the scan in tests)."""
    B, S, D = x.shape
    if S % chunk or S <= chunk:
        return time_mix(x, x_prev, S0, p, n_heads, head_dim)
    xs = token_shift(x, x_prev)
    xr = _mix(x, xs, p["mu_r"])
    xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"])
    xw = _mix(x, xs, p["mu_w"])
    xg = _mix(x, xs, p["mu_g"])
    r = (xr @ p["wr"]).astype(jnp.float32)
    k = (xk @ p["wk"]).astype(jnp.float32)
    v = (xv @ p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    wln = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wa"]) @ p["wb"]
    w = jnp.exp(-jnp.exp(wln.astype(jnp.float32)))

    H, hd = n_heads, head_dim
    nc = S // chunk
    shp = (B, nc, chunk, H, hd)
    # (B,nc,H,chunk,hd) chunk-major
    rc, kc, vc, wc = (t.reshape(shp).transpose(0, 1, 3, 2, 4)
                      for t in (r, k, v, w))
    u = p["u"].reshape(H, hd).astype(jnp.float32)

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=3)                  # (B,nc,H,C,hd)
    P = jnp.exp(cum - logw)                         # prefix EXCLUSIVE
    rP = rc * P
    kD = kc * jnp.exp(-cum)
    A = jnp.einsum("bnhtd,bnhsd->bnhts", rP, kD)    # (B,nc,H,C,C)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    diag = jnp.einsum("bnhtd,hd,bnhtd->bnht", rc, u, kc)
    y_intra = jnp.einsum("bnhts,bnhsd->bnhtd", A, vc) + diag[..., None] * vc
    total = cum[:, :, :, -1]                        # (B,nc,H,hd)
    kT = kc * jnp.exp(total[:, :, :, None] - cum)
    dS = jnp.einsum("bnhsd,bnhse->bnhde", kT, vc)   # per-chunk state delta
    decay = jnp.exp(total)                          # (B,nc,H,hd)

    def body(Sc, inp):
        rPn, dSn, dn = inp                          # (B,H,C,hd),(B,H,hd,hd),(B,H,hd)
        y_cross = jnp.einsum("bhtd,bhde->bhte", rPn, Sc)
        Sc = dn[..., None] * Sc + dSn
        return Sc, y_cross

    sf = lambda t: t.swapaxes(0, 1)                 # chunk axis first
    S_out, y_cross = jax.lax.scan(
        body, S0.astype(jnp.float32),
        (sf(rP), sf(dS), sf(decay)))
    y = y_intra + y_cross.swapaxes(0, 1)            # (B,nc,H,C,hd)
    y = y.transpose(0, 1, 3, 2, 4).reshape(B, S, D)
    yh = y.reshape(B, S, H, hd)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(B, S, D) * p["ln_w"] + p["ln_b"]).astype(x.dtype)
    y = (y * g).astype(x.dtype) @ p["wo"]
    return y, S_out, x[:, -1:]


def channel_mix(x: jax.Array, x_prev: jax.Array, p: dict
                ) -> Tuple[jax.Array, jax.Array]:
    xs = token_shift(x, x_prev)
    xk = _mix(x, xs, p["mu_ck"])
    xr = _mix(x, xs, p["mu_cr"])
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    r = jax.nn.sigmoid(xr @ p["cr"])
    return r * (k @ p["cv"]), x[:, -1:]
